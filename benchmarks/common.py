"""Shared harness for the paper-table benchmarks.

Each benchmark runs the paper's *protocol* at CPU scale: pre-train a reduced
same-family model on synthetic Markov data, record FP perplexity + outlier
metrics (max inf-norm, avg kurtosis over attention-layer outputs), then
apply the paper's PTQ recipe (symmetric-weight/asymmetric-activation,
static ranges) and record quantized perplexity.

Step counts scale with REPRO_BENCH_STEPS (default 200; CI smoke uses 20).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


from repro.configs import apply_method
from repro.configs.paper_models import bert_tiny, opt_tiny
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import model_apply
from repro.optim import AdamWConfig, linear_warmup_linear_decay
from repro.quant import QConfig, QuantContext, calibrate, evaluate_perplexity
from repro.train import LoopConfig, TrainTask, evaluate, run_training
from repro.train.losses import loss_for

BENCH_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "200"))
VOCAB = 512


def bench_steps(scale: float = 1.0) -> int:
    return max(int(BENCH_STEPS * scale), 5)


def make_family(family: str, seq_len: int = 64):
    """'bert' (MLM, post-LN encoder) or 'opt' (CLM, pre-LN decoder)."""
    if family == "bert":
        return bert_tiny(vocab=VOCAB, seq_len=seq_len), "mlm"
    return opt_tiny(vocab=VOCAB, seq_len=seq_len), "clm"


def train_and_measure(
    cfg,
    loss_kind: str,
    steps: Optional[int] = None,
    lr: float = 2e-3,
    seed: int = 0,
    batch_size: int = 16,
    qconfig: Optional[QConfig] = None,
) -> Dict[str, float]:
    """Paper protocol: pre-train -> (FP ppl, inf-norm, kurtosis, W8A8 ppl)."""
    steps = steps or BENCH_STEPS
    task = TrainTask(cfg=cfg, loss_kind=loss_kind,
                     optimizer=AdamWConfig(lr=lr),
                     schedule=linear_warmup_linear_decay(steps // 10, steps))
    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=cfg.max_seq_len
        if cfg.max_seq_len <= 256 else 64,
        batch_size=batch_size, seed=seed))
    t0 = time.perf_counter()
    out = run_training(task, data, LoopConfig(
        total_steps=steps, eval_every=0, log_every=0), batch_kind=loss_kind)
    train_s = time.perf_counter() - t0
    params = out["state"].params
    ppl, ostats = evaluate(task, params, data, n_batches=4, batch_kind=loss_kind)

    res = {
        "fp_ppl": ppl,
        "max_inf_norm": ostats["max_inf_norm"],
        "avg_kurtosis": ostats["avg_kurtosis"],
        "train_s": train_s,
        "s_per_step": train_s / steps,
    }

    # ---- PTQ (paper Sec. 5 'Quantization setup') ----
    qc = qconfig or QConfig(act_estimator="running_minmax")

    def apply_fn(p, batch, ctx):
        logits, _ = model_apply(p, cfg, batch, ctx=ctx)
        return logits

    def loss_fn(p, batch, ctx):
        ctx = ctx if ctx is not None else QuantContext(None)
        logits, _ = model_apply(p, cfg, batch, ctx=ctx)
        return loss_for(loss_kind)(logits, jnp.asarray(batch["labels"]))

    q_ppls = []
    for cal_seed in range(2):
        cal = [jax.tree_util.tree_map(
            jnp.asarray, data.batch(5_000_000 + 100 * cal_seed + i, loss_kind))
            for i in range(8)]
        ctx = calibrate(apply_fn, params, cal, qc, num_batches=8)
        ev = [jax.tree_util.tree_map(
            jnp.asarray, data.batch(10_000_000 + i, loss_kind))
            for i in range(4)]
        q_loss = jax.jit(lambda p, b: loss_fn(p, b, ctx))
        q_ppls.append(evaluate_perplexity(
            lambda p, b, _ctx: q_loss(p, b), params, ev, ctx, 4))
    res["w8a8_ppl"] = float(np.mean(q_ppls))
    res["w8a8_ppl_std"] = float(np.std(q_ppls))
    res["params"] = params
    res["task"] = task
    res["data"] = data
    return res


def fmt_row(name: str, r: Dict[str, float]) -> str:
    return (f"{name},{r['fp_ppl']:.3f},{r['max_inf_norm']:.2f},"
            f"{r['avg_kurtosis']:.1f},{r['w8a8_ppl']:.3f},"
            f"{r['s_per_step']*1e6:.0f}")


HEADER = "name,fp_ppl,max_inf_norm,avg_kurtosis,w8a8_ppl,us_per_step"
