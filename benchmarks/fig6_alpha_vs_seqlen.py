"""Paper Figure 6: gamma = -alpha/T parameterization across sequence
lengths — alpha in [2, 4] should hold up across T (BERT-6L protocol,
reduced)."""
from __future__ import annotations

from benchmarks.common import bench_steps, HEADER, fmt_row, train_and_measure
from repro.configs import apply_method
from repro.configs.paper_models import bert_tiny

ALPHAS = [0.5, 2.0, 4.0, 8.0]
SEQ_LENS = [32, 64, 128]


def run(print_fn=print) -> None:
    print_fn("# Fig 6 — gamma = -alpha/T vs sequence length [BERT-family]")
    print_fn("seq_len,alpha," + HEADER.split(",", 1)[1])
    for t in SEQ_LENS:
        for alpha in ALPHAS:
            cfg = apply_method(bert_tiny(vocab=512, seq_len=t),
                               "clipped_softmax", alpha=alpha)
            r = train_and_measure(cfg, "mlm", steps=bench_steps(0.4))
            print_fn(f"{t},{alpha}," + fmt_row("", r).split(",", 1)[1])


if __name__ == "__main__":
    run()
