"""Paper Figure 7: gated-attention bias init (pi_init) sweep — very low
pi_init hurts FP quality, very high behaves like vanilla (outliers return);
the useful band is wide (robustness claim)."""
from __future__ import annotations

from benchmarks.common import bench_steps, HEADER, fmt_row, make_family, train_and_measure
from repro.configs import apply_method

PI_INITS = [0.05, 0.25, 0.5, 0.9, 0.99]


def run(print_fn=print) -> None:
    cfg0, loss_kind = make_family("bert")
    print_fn("# Fig 7 — gated attention pi_init sweep [BERT-family]")
    print_fn("pi_init," + HEADER.split(",", 1)[1])
    for pi in PI_INITS:
        cfg = apply_method(cfg0, "gated_attention", pi_init=pi)
        r = train_and_measure(cfg, loss_kind, steps=bench_steps(0.5))
        print_fn(f"{pi}," + fmt_row("", r).split(",", 1)[1])


if __name__ == "__main__":
    run()
