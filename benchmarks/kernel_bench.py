"""Kernel micro-benchmarks: Pallas (interpret) correctness-scale timings +
the XLA twins that actually run on CPU, plus int8-vs-float quality, plus
the paged-attention decode-tick scaling study (gather vs fused kernel
across block-table widths W, written to BENCH_paged_kernel.json). On TPU
the same harness times the compiled kernels (interpret=False)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import AttentionConfig, chunked_attention, dense_attention, paged_attention
from repro.core.softmax import ClippedSoftmaxConfig
from repro.kernels import default_interpret, linear_w8a8, on_tpu, quantize_weights_int8

_BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                           "BENCH_paged_kernel.json")


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_paged(print_fn=print, out_path: str = _BENCH_JSON) -> None:
    """Paged decode-tick scaling: one attention read per tick at batch B,
    each row holding ``live`` allocated blocks, as the block-table width W
    (the per-row logical capacity, max_len / block_size) grows.

    Three series per softmax variant:

      * ``gather_full``  — PR 2's status quo: the XLA gather materializes
        the full (B, W*block_size, Hkv, Dh) virtual sequence; cost grows
        linearly in W no matter how few tokens are live.
      * ``gather_live``  — the gather sliced to the allocated prefix via
        the scheduler's static ``live_width``; flat in W.
      * ``kernel_live``  — the fused Pallas kernel over the same prefix:
        in-place pool-block reads, no materialization. On CPU this column
        is INTERPRET-mode timing (absolute value meaningless, flatness in
        W is the claim); on TPU it is the compiled kernel.

    Results append-print as CSV and land in BENCH_paged_kernel.json so the
    perf trajectory is diffable across PRs."""
    B, HQ, HKV, DH, BS, LIVE = 4, 4, 2, 64, 16, 2
    WS = (8, 16, 32, 64)
    interpret = default_interpret()
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    pos = jnp.asarray([LIVE * BS - 1 - 3 * i for i in range(B)], jnp.int32)
    gate = jax.nn.sigmoid(jax.random.normal(ks[3], (B, 1, HQ)))
    variants = (("vanilla", ClippedSoftmaxConfig(), None),
                ("clipped", ClippedSoftmaxConfig(alpha=4.0), None),
                ("gated", ClippedSoftmaxConfig(alpha=4.0), gate))

    print_fn(f"# paged decode tick: B={B} Hq={HQ} Hkv={HKV} Dh={DH} "
             f"block_size={BS}, {LIVE} live blocks/row; kernel timings are "
             f"{'INTERPRET-mode (flatness in W is the claim)' if interpret else 'compiled'}")
    print_fn("variant,W,gather_full_us,gather_live_us,kernel_live_us")
    rows = []
    for name, sm, gp in variants:
        cfg = AttentionConfig(n_heads=HQ, n_kv_heads=HKV, d_head=DH,
                              softmax=sm)
        for w in WS:
            nb = B * LIVE + 2
            q = jax.random.normal(ks[0], (B, 1, HQ, DH))
            k_pool = jax.random.normal(ks[1], (nb, BS, HKV, DH))
            v_pool = jax.random.normal(ks[2], (nb, BS, HKV, DH))
            table = np.full((B, w), -1, np.int32)
            for i in range(B):
                table[i, :LIVE] = range(i * LIVE, (i + 1) * LIVE)
            table = jnp.asarray(table)

            def f(backend, lw):
                return jax.jit(lambda q, t: paged_attention(
                    q, k_pool, v_pool, t, cfg, q_offset=pos, gate_pi=gp,
                    backend=backend, live_width=lw, interpret=interpret))

            t_full = _time(f("gather", None), q, table)
            t_live = _time(f("gather", LIVE), q, table)
            t_kern = _time(f("kernel", LIVE), q, table)
            print_fn(f"{name},{w},{t_full*1e6:.0f},{t_live*1e6:.0f},"
                     f"{t_kern*1e6:.0f}")
            rows.append(dict(variant=name, W=w,
                             gather_full_us=round(t_full * 1e6, 1),
                             gather_live_us=round(t_live * 1e6, 1),
                             kernel_live_us=round(t_kern * 1e6, 1)))
    payload = {
        "meta": dict(B=B, Hq=HQ, Hkv=HKV, Dh=DH, block_size=BS,
                     live_blocks=LIVE, widths=list(WS),
                     backend=jax.default_backend(),
                     kernel_interpret_mode=interpret, on_tpu=on_tpu(),
                     note="gather_full scans the whole table width W; "
                          "gather_live/kernel_live visit only the allocated "
                          "prefix (scheduler live_width) and should be flat "
                          "in W. Interpret-mode kernel timings are only "
                          "meaningful for that flatness, not absolutely."),
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print_fn(f"# wrote {os.path.relpath(out_path)}")


def run(print_fn=print) -> None:
    print_fn("# Kernel micro-bench (CPU host; XLA paths timed, Pallas "
             "kernels are TPU-target and validated in tests)")
    print_fn("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    B, T, H, HKV, D = 2, 512, 8, 4, 64
    q = jax.random.normal(key, (B, T, H, D), jnp.float32)
    k = jax.random.normal(key, (B, T, HKV, D), jnp.float32)
    v = jax.random.normal(key, (B, T, HKV, D), jnp.float32)

    for name, sm in (("attn_vanilla", ClippedSoftmaxConfig()),
                     ("attn_clipped", ClippedSoftmaxConfig(gamma=-0.03))):
        cfg = AttentionConfig(n_heads=H, n_kv_heads=HKV, d_head=D,
                              softmax=sm, chunk_size=128)
        f_dense = jax.jit(lambda q, k, v, c=cfg: dense_attention(q, k, v, c))
        f_chunk = jax.jit(lambda q, k, v, c=cfg: chunked_attention(q, k, v, c))
        td = _time(f_dense, q, k, v)
        tc = _time(f_chunk, q, k, v)
        flops = 4 * B * T * T * H * D
        print_fn(f"{name}_dense,{td*1e6:.0f},{flops/td/1e9:.1f}GFLOP/s")
        print_fn(f"{name}_chunked,{tc*1e6:.0f},{flops/tc/1e9:.1f}GFLOP/s")

    bench_paged(print_fn)

    # int8 path quality + time (XLA fallback timing on CPU)
    x = jax.random.normal(key, (256, 512))
    w = jax.random.normal(key, (512, 256)) * 0.05
    wq, ws = quantize_weights_int8(w)
    f = x @ w
    o = linear_w8a8(x, wq, ws)
    rel = float(jnp.mean(jnp.abs(o - f)) / jnp.mean(jnp.abs(f)))
    tf = _time(jax.jit(lambda a, b: a @ b), x, w)
    print_fn(f"matmul_f32,{tf*1e6:.0f},w8a8_rel_err={rel:.4f}")


if __name__ == "__main__":
    run()
