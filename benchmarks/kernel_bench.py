"""Kernel micro-benchmarks: Pallas (interpret) correctness-scale timings +
the XLA twins that actually run on CPU, plus int8-vs-float quality. On TPU
the same harness times the compiled kernels (interpret=False)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionConfig, chunked_attention, dense_attention
from repro.core.softmax import ClippedSoftmaxConfig
from repro.kernels import linear_w8a8, quantize_weights_int8


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(print_fn=print) -> None:
    print_fn("# Kernel micro-bench (CPU host; XLA paths timed, Pallas "
             "kernels are TPU-target and validated in tests)")
    print_fn("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    B, T, H, HKV, D = 2, 512, 8, 4, 64
    q = jax.random.normal(key, (B, T, H, D), jnp.float32)
    k = jax.random.normal(key, (B, T, HKV, D), jnp.float32)
    v = jax.random.normal(key, (B, T, HKV, D), jnp.float32)

    for name, sm in (("attn_vanilla", ClippedSoftmaxConfig()),
                     ("attn_clipped", ClippedSoftmaxConfig(gamma=-0.03))):
        cfg = AttentionConfig(n_heads=H, n_kv_heads=HKV, d_head=D,
                              softmax=sm, chunk_size=128)
        f_dense = jax.jit(lambda q, k, v, c=cfg: dense_attention(q, k, v, c))
        f_chunk = jax.jit(lambda q, k, v, c=cfg: chunked_attention(q, k, v, c))
        td = _time(f_dense, q, k, v)
        tc = _time(f_chunk, q, k, v)
        flops = 4 * B * T * T * H * D
        print_fn(f"{name}_dense,{td*1e6:.0f},{flops/td/1e9:.1f}GFLOP/s")
        print_fn(f"{name}_chunked,{tc*1e6:.0f},{flops/tc/1e9:.1f}GFLOP/s")

    # int8 path quality + time (XLA fallback timing on CPU)
    x = jax.random.normal(key, (256, 512))
    w = jax.random.normal(key, (512, 256)) * 0.05
    wq, ws = quantize_weights_int8(w)
    f = x @ w
    o = linear_w8a8(x, wq, ws)
    rel = float(jnp.mean(jnp.abs(o - f)) / jnp.mean(jnp.abs(f)))
    tf = _time(jax.jit(lambda a, b: a @ b), x, w)
    print_fn(f"matmul_f32,{tf*1e6:.0f},w8a8_rel_err={rel:.4f}")


if __name__ == "__main__":
    run()
