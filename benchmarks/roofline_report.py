"""Aggregate the dry-run JSONs into the §Roofline table (reads
experiments/dryrun/*.json written by repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh_tag: str = "16x16"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        if rep.get("mesh_tag") == mesh_tag:
            rep["_profile"] = rep.get("profile", "tp_fsdp")
            cells.append(rep)
    return cells


def run(print_fn=print, mesh_tag: str = "16x16") -> None:
    cells = load_cells(mesh_tag)
    if not cells:
        print_fn(f"# Roofline — no dry-run results yet (run "
                 f"`python -m repro.launch.dryrun --all`)")
        return
    print_fn(f"# Roofline table — mesh {mesh_tag} (terms in seconds/step, "
             "per-device basis)")
    print_fn("arch,shape,profile,status,compute_s,memory_s,collective_s,"
             "bottleneck,useful_flops_ratio")
    n_ok = 0
    for rep in cells:
        if rep["status"] == "ok":
            r = rep["roofline"]
            ratio = r.get("useful_flops_ratio")
            pr = rep["_profile"]
            ratio_s = f"{ratio:.3f}" if ratio else "n/a"
            print_fn(f"{rep['arch']},{rep['shape']},{pr},ok,"
                     f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
                     f"{r['collective_s']:.4f},{r['bottleneck']},{ratio_s}")
            n_ok += 1
        else:
            reason = rep.get("reason", rep.get("error", ""))[:60].replace(
                ",", ";").replace("\n", " ")
            print_fn(f"{rep['arch']},{rep['shape']},{rep.get('_profile','')},"
                     f"{rep['status']},,,,,{reason}")
    print_fn(f"# {n_ok} compiled cells")


if __name__ == "__main__":
    run()
