"""Benchmark entrypoint: one section per paper table/figure + kernel
micro-bench + the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run            # full (~REPRO_BENCH_STEPS)
    REPRO_BENCH_STEPS=20 PYTHONPATH=src python -m benchmarks.run   # smoke

Sections print CSV blocks (``name,us_per_call,derived``-style columns per
table)."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (  # noqa: E402
    fig6_alpha_vs_seqlen,
    fig7_bias_init,
    kernel_bench,
    roofline_report,
    table1_clipped_softmax,
    table2_main,
    table4_gating_arch,
    table10_bitwidths,
    table11_overhead,
)

SECTIONS = [
    ("table2_main", table2_main.run),
    ("table1_clipped_softmax", table1_clipped_softmax.run),
    ("fig6_alpha_vs_seqlen", fig6_alpha_vs_seqlen.run),
    ("fig7_bias_init", fig7_bias_init.run),
    ("table4_gating_arch", table4_gating_arch.run),
    ("table10_bitwidths", table10_bitwidths.run),
    ("table11_overhead", table11_overhead.run),
    ("kernel_bench", kernel_bench.run),
    ("roofline_report", roofline_report.run),
]


def main() -> None:
    only = set(sys.argv[1:])
    t_all = time.time()
    for name, fn in SECTIONS:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"SECTION FAILED: {name}: {e!r}")
        print(f"# section {name} took {time.time()-t0:.1f}s", flush=True)
    print(f"\n# total {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
