"""Serving throughput: decode tokens/sec vs batch size for the paper's
three attention variants (vanilla, clipped softmax, gated attention) on the
fused decode engine, plus a continuous-batching run with staggered request
lengths — the Table 11-style serving companion: the paper's methods must
not cost decode throughput.

Two measurements per (method, batch):
  * ``generate``           — one jitted lax.while_loop for the whole decode;
  * ``ContinuousBatcher``  — per-slot positions, every active slot decodes
    every tick (throughput scales with active slots, not cohort size).

    PYTHONPATH=src python benchmarks/serving_throughput.py
Scale with REPRO_BENCH_STEPS (default 200 -> max_new_tokens 32).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_method
from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import ContinuousBatcher, GenerateConfig, Request, generate

VOCAB = 256
PROMPT_LEN = 8
MAX_NEW = max(int(os.environ.get("REPRO_BENCH_STEPS", "200")) // 6, 8)
BATCHES = (1, 2, 4, 8)

METHODS = [
    ("vanilla", None, {}),
    ("clipped_softmax", "clipped_softmax", {"alpha": 4.0}),
    ("gated_attention", "gated_attention", {"pi_init": 0.5}),
]


def make(method, kwargs):
    cfg = opt_tiny(vocab=VOCAB, seq_len=64)
    if method is not None:
        cfg = apply_method(cfg, method, **kwargs)
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def bench_generate(cfg, params, b: int, reps: int = 3) -> float:
    gen = GenerateConfig(max_new_tokens=MAX_NEW)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, PROMPT_LEN), 4, VOCAB)
    generate(params, cfg, prompts, gen).block_until_ready()   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        generate(params, cfg, prompts, gen).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return b * MAX_NEW / dt


def bench_batcher(cfg, params, b: int, n_req: int = None) -> float:
    n_req = n_req or 2 * b
    rng = np.random.default_rng(0)
    reqs = [(i,
             rng.integers(4, VOCAB, size=int(rng.integers(4, PROMPT_LEN + 1))
                          ).astype(np.int32),
             int(rng.integers(MAX_NEW // 2, MAX_NEW + 1)))
            for i in range(n_req)]
    batcher = ContinuousBatcher(params, cfg, batch_size=b,
                                max_len=PROMPT_LEN + MAX_NEW + 8)
    # warm-up pass over the same request mix compiles every prefill/decode
    # shape on this batcher's jit cache, so the timed pass measures serving
    # throughput, not XLA compilation (mirrors bench_generate)
    for warm in (True, False):
        for uid, prompt, mnt in reqs:
            batcher.submit(Request(uid=uid, prompt=prompt.copy(),
                                   max_new_tokens=mnt))
        if warm:
            batcher.run()
            batcher.done.clear()
        else:
            t0 = time.perf_counter()
            done = batcher.run()
            dt = time.perf_counter() - t0
    return sum(len(r.output) for r in done) / dt


def main() -> None:
    print(f"decode throughput, max_new_tokens={MAX_NEW}, prompt={PROMPT_LEN}")
    print("method,batch,generate_tok_s,batcher_tok_s")
    for name, method, kwargs in METHODS:
        cfg, params = make(method, kwargs)
        for b in BATCHES:
            g = bench_generate(cfg, params, b)
            s = bench_batcher(cfg, params, b)
            print(f"{name},{b},{g:.1f},{s:.1f}")


if __name__ == "__main__":
    main()
