"""Serving throughput: decode tokens/sec + KV-pool capacity for the fused
per-slot-position decode engine.

This is the serving companion to paper Table 11 (runtime overhead): the
paper's quantization-enabling methods (clipped softmax Sec. 4.1, gated
attention Sec. 4.2) must not cost decode throughput, and the serving engine
is where that bill would come due. No direct paper figure — the paper stops
at PTQ accuracy; this script covers the deployment half of its claim.

Three sections:

  1. ``method x batch`` — tok/s for vanilla / clipped_softmax /
     gated_attention under both entry points:
       * ``generate``           — one jitted lax.while_loop per batch;
       * ``ContinuousBatcher``  — per-slot positions, every active slot
         decodes every tick (throughput scales with active slots, not
         cohort size).
  2. ``dense vs paged capacity`` — same total KV memory (N dense slots of
     ``max_len`` == N*max_len/block_size pool blocks), mixed prompt
     lengths: how many requests run concurrently under each allocator
     (paged admits ~3x here: blocks scale with live tokens, slots with
     worst case).
  3. ``dense vs paged throughput`` — end-to-end tok/s over the same mixed
     request stream. Paged finishes in ~half the ticks (more rows in
     flight); each tick's attention read visits only the allocated
     block-table prefix (the scheduler's static ``live_width`` — Pallas
     kernel on TPU, sliced XLA gather on CPU), so the read cost tracks
     live tokens, but at this CPU toy scale the model matmuls dominate
     and tok/s lands near parity. The read-path scaling itself is
     isolated in ``kernel_bench.py`` (BENCH_paged_kernel.json).

    PYTHONPATH=src python benchmarks/serving_throughput.py
Scale with REPRO_BENCH_STEPS (default 200 -> max_new_tokens 32).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_method
from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import ContinuousBatcher, GenerateConfig, Request, generate

VOCAB = 256
PROMPT_LEN = 8
MAX_NEW = max(int(os.environ.get("REPRO_BENCH_STEPS", "200")) // 6, 8)
BATCHES = (1, 2, 4, 8)

METHODS = [
    ("vanilla", None, {}),
    ("clipped_softmax", "clipped_softmax", {"alpha": 4.0}),
    ("gated_attention", "gated_attention", {"pi_init": 0.5}),
]


def make(method, kwargs):
    cfg = opt_tiny(vocab=VOCAB, seq_len=64)
    if method is not None:
        cfg = apply_method(cfg, method, **kwargs)
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def bench_generate(cfg, params, b: int, reps: int = 3) -> float:
    gen = GenerateConfig(max_new_tokens=MAX_NEW)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, PROMPT_LEN), 4, VOCAB)
    generate(params, cfg, prompts, gen).block_until_ready()   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        generate(params, cfg, prompts, gen).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return b * MAX_NEW / dt


def bench_batcher(cfg, params, b: int, n_req: int = None) -> float:
    n_req = n_req or 2 * b
    rng = np.random.default_rng(0)
    reqs = [(i,
             rng.integers(4, VOCAB, size=int(rng.integers(4, PROMPT_LEN + 1))
                          ).astype(np.int32),
             int(rng.integers(MAX_NEW // 2, MAX_NEW + 1)))
            for i in range(n_req)]
    batcher = ContinuousBatcher(params, cfg, batch_size=b,
                                max_len=PROMPT_LEN + MAX_NEW + 8)
    # warm-up pass over the same request mix compiles every prefill/decode
    # shape on this batcher's jit cache, so the timed pass measures serving
    # throughput, not XLA compilation (mirrors bench_generate)
    for warm in (True, False):
        for uid, prompt, mnt in reqs:
            batcher.submit(Request(uid=uid, prompt=prompt.copy(),
                                   max_new_tokens=mnt))
        if warm:
            batcher.run()
            batcher.done.clear()
        else:
            t0 = time.perf_counter()
            done = batcher.run()
            dt = time.perf_counter() - t0
    return sum(len(r.output) for r in done) / dt


def _mixed_requests(n_req: int, max_len: int, seed: int = 0):
    """Mixed prompt lengths from a few fixed buckets (bounds XLA compiles)
    plus a long-prompt straggler every 8th request — the workload where a
    dense slot pool wastes most of its reservation (short requests) AND has
    a whole-slot hog (the straggler)."""
    rng = np.random.default_rng(seed)
    buckets = (8, 16, 32)
    straggler = max(2 * max_len // 3, max(buckets))
    reqs = []
    for i in range(n_req):
        t = straggler if i % 8 == 4 else int(buckets[i % len(buckets)])
        reqs.append((i, rng.integers(4, VOCAB, size=t).astype(np.int32),
                     int(rng.integers(MAX_NEW // 2, MAX_NEW + 1))))
    return reqs


def bench_paged_vs_dense(cfg, params, n_dense_slots: int = 2,
                         max_len: int = 96, block_size: int = 16):
    """Equal-memory comparison: N dense slots of ``max_len`` vs a paged pool
    of N*max_len/block_size blocks spread over 4N batch rows. Returns
    (concurrency, tok/s) per allocator over the same request stream."""
    n_req = 8 * n_dense_slots
    num_blocks = n_dense_slots * max_len // block_size

    def build(paged: bool) -> ContinuousBatcher:
        if paged:
            return ContinuousBatcher(params, cfg,
                                     batch_size=4 * n_dense_slots,
                                     max_len=max_len, paged=True,
                                     block_size=block_size,
                                     num_blocks=num_blocks)
        return ContinuousBatcher(params, cfg, batch_size=n_dense_slots,
                                 max_len=max_len)

    out = {}
    for paged in (False, True):
        batcher = build(paged)          # blocks/slots fully reclaim per run,
        concurrency, dt, done = 0, 0.0, []   # so one batcher serves both passes
        for warm in (True, False):
            for uid, prompt, mnt in _mixed_requests(n_req, max_len):
                batcher.submit(Request(uid=uid, prompt=prompt.copy(),
                                       max_new_tokens=mnt))
            if warm:
                batcher.run()           # compile every prefill/decode shape
                batcher.done.clear()
            else:
                t0 = time.perf_counter()
                concurrency = batcher.step()
                done = batcher.run()
                dt = time.perf_counter() - t0
        tok_s = sum(len(r.output) for r in done) / dt
        out["paged" if paged else "dense"] = (concurrency, tok_s)
    return out


def main() -> None:
    print(f"decode throughput, max_new_tokens={MAX_NEW}, prompt={PROMPT_LEN}")
    print("method,batch,generate_tok_s,batcher_tok_s")
    for name, method, kwargs in METHODS:
        cfg, params = make(method, kwargs)
        for b in BATCHES:
            g = bench_generate(cfg, params, b)
            s = bench_batcher(cfg, params, b)
            print(f"{name},{b},{g:.1f},{s:.1f}")

    print("\n# dense vs paged KV cache, equal pool memory "
          "(N dense slots == N*max_len/block_size blocks), mixed prompts")
    print("allocator,concurrent_requests,tok_s")
    cfg, params = make(None, {})
    for alloc, (conc, tok_s) in bench_paged_vs_dense(cfg, params).items():
        print(f"{alloc},{conc},{tok_s:.1f}")


if __name__ == "__main__":
    main()
