"""Serving throughput: decode tokens/sec + KV-pool capacity for the fused
per-slot-position decode engine.

This is the serving companion to paper Table 11 (runtime overhead): the
paper's quantization-enabling methods (clipped softmax Sec. 4.1, gated
attention Sec. 4.2) must not cost decode throughput, and the serving engine
is where that bill would come due. No direct paper figure — the paper stops
at PTQ accuracy; this script covers the deployment half of its claim.

Three sections:

  1. ``method x batch`` — tok/s for vanilla / clipped_softmax /
     gated_attention under both entry points:
       * ``generate``           — one jitted lax.while_loop per batch;
       * ``ContinuousBatcher``  — per-slot positions, every active slot
         decodes every tick (throughput scales with active slots, not
         cohort size).
  2. ``dense vs paged capacity`` — same total KV memory (N dense slots of
     ``max_len`` == N*max_len/block_size pool blocks), mixed prompt
     lengths: how many requests run concurrently under each allocator
     (paged admits ~3x here: blocks scale with live tokens, slots with
     worst case).
  3. ``dense vs paged throughput`` — end-to-end tok/s over the same mixed
     request stream. Paged finishes in ~half the ticks (more rows in
     flight); each tick's attention read visits only the allocated
     block-table prefix (the scheduler's static ``live_width`` — Pallas
     kernel on TPU, sliced XLA gather on CPU), so the read cost tracks
     live tokens, but at this CPU toy scale the model matmuls dominate
     and tok/s lands near parity. The read-path scaling itself is
     isolated in ``kernel_bench.py`` (BENCH_paged_kernel.json).
  4. ``prefill interleaving / TTFT`` — a long prompt arrives while another
     request is decoding. With a one-shot-sized ``token_budget`` the whole
     prompt lands in ONE tick (the old admit-then-decode shape): that tick
     is the decode stall — the decoding row's inter-token latency spikes
     to the full prefill time. A chunked budget bounds every mixed tick,
     so the max tick time during admission (= the stall) drops while
     time-to-first-token stays in the same ballpark (chunks and decode
     share each forward). Reported per budget: max/median tick latency
     over the admission window and the long request's TTFT.
  5. ``int8 vs fp serving`` — the W8A8 + int8-KV engine
     (``qconfig=QConfig()``) against the fp engine on the same request
     stream: tok/s, greedy token agreement %, and equal-byte-pool
     capacity (peak concurrently advancing rows; the int8 pool holds
     ~3.5x the blocks of an f32 pool — ``paged_kv_block_bytes``). A tiny
     model is TRAINED on the synthetic chain first: random-init argmax is
     a coin flip, so agreement is only meaningful once greedy margins are
     decisive (see tests/test_int8_serving_quality.py); ``--smoke`` trains
     just long enough to exercise the path, so its agreement column is
     noisy by design.
  6. ``open-loop goodput`` — the same engines under *open-loop* seeded
     traffic (``serving.workload``): Poisson arrivals at several offered
     rates, heavy-tailed lengths, priority tiers with deadlines, run on
     the deterministic virtual clock. Closed-loop tok/s hides overload
     behaviour entirely; here the headline is **goodput** (tokens of
     requests that finished inside their SLO) per tier, plus shed counts
     — at low offered load goodput tracks delivered tokens; past
     saturation the engine sheds low-priority work by policy while the
     interactive tier's in-SLO fraction degrades last. fp vs int8-KV on
     the same trace at every rate, directly comparable.
  7. ``prefix sharing`` — the prefix-cache subsystem
     (``serving.prefix_cache``, ``prefix_cache=True``). First TTFT in
     *ticks* (deterministic, clock-free): the same prompt admitted cold
     runs every prefill chunk; admitted again it maps the cached blocks
     and reaches its first token in ONE tick, running only the uncached
     tail. Then equal-byte concurrency: the same seeded open-loop trace
     (section 6 machinery) at several prompt-overlap ratios
     (``WorkloadConfig.prefix_len/prefix_frac`` — a fixed system prompt
     a fraction of requests share), served with sharing off vs on from
     an IDENTICAL block pool. Sharing turns duplicated prompt blocks
     into refcounts, so the saved blocks and prefill tokens show up as
     goodput/in-SLO headroom that widens with the overlap ratio — and
     costs nothing at zero overlap (the trie just misses).
  8. ``speculative decoding`` — the spec subsystem (``serving.speculate``,
     ``spec=SpecConfig(k)``): decode tok/s and accept rate vs draft
     length k, repetitive vs random prompts, fp vs int8-KV. The n-gram
     drafter is model-free and verification is bitwise-lossless, so the
     table is pure throughput: repetitive streams accept most drafts
     and multiply decode tok/s; random streams bound the rejection
     overhead. Written to BENCH_spec_decode.json.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--smoke]
Scale with REPRO_BENCH_STEPS (default 200 -> max_new_tokens 32).
``--smoke`` runs every section once at toy sizes with no timing loops —
a CI crash-detector for the engine paths, not a benchmark.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_method
from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import ContinuousBatcher, GenerateConfig, Request, generate

SMOKE = "--smoke" in sys.argv
VOCAB = 256
PROMPT_LEN = 8
MAX_NEW = 8 if SMOKE else max(int(os.environ.get("REPRO_BENCH_STEPS", "200")) // 6, 8)
BATCHES = (2,) if SMOKE else (1, 2, 4, 8)

METHODS = [
    ("vanilla", None, {}),
    ("clipped_softmax", "clipped_softmax", {"alpha": 4.0}),
    ("gated_attention", "gated_attention", {"pi_init": 0.5}),
]


def make(method, kwargs):
    cfg = opt_tiny(vocab=VOCAB, seq_len=64)
    if method is not None:
        cfg = apply_method(cfg, method, **kwargs)
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def bench_generate(cfg, params, b: int, reps: int = 3) -> float:
    gen = GenerateConfig(max_new_tokens=MAX_NEW)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, PROMPT_LEN), 4, VOCAB)
    generate(params, cfg, prompts, gen).block_until_ready()   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        generate(params, cfg, prompts, gen).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return b * MAX_NEW / dt


def bench_batcher(cfg, params, b: int, n_req: int = None) -> float:
    n_req = n_req or 2 * b
    rng = np.random.default_rng(0)
    reqs = [(i,
             rng.integers(4, VOCAB, size=int(rng.integers(4, PROMPT_LEN + 1))
                          ).astype(np.int32),
             int(rng.integers(MAX_NEW // 2, MAX_NEW + 1)))
            for i in range(n_req)]
    batcher = ContinuousBatcher(params, cfg, batch_size=b,
                                max_len=PROMPT_LEN + MAX_NEW + 8)
    # warm-up pass over the same request mix compiles every prefill/decode
    # shape on this batcher's jit cache, so the timed pass measures serving
    # throughput, not XLA compilation (mirrors bench_generate)
    for warm in (True, False):
        for uid, prompt, mnt in reqs:
            batcher.submit(Request(uid=uid, prompt=prompt.copy(),
                                   max_new_tokens=mnt))
        if warm:
            batcher.run()
            batcher.done.clear()
        else:
            t0 = time.perf_counter()
            done = batcher.run()
            dt = time.perf_counter() - t0
    return sum(len(r.output) for r in done) / dt


def _mixed_requests(n_req: int, max_len: int, seed: int = 0):
    """Mixed prompt lengths from a few fixed buckets (bounds XLA compiles)
    plus a long-prompt straggler every 8th request — the workload where a
    dense slot pool wastes most of its reservation (short requests) AND has
    a whole-slot hog (the straggler)."""
    rng = np.random.default_rng(seed)
    buckets = (8, 16, 32)
    straggler = max(2 * max_len // 3, max(buckets))
    reqs = []
    for i in range(n_req):
        t = straggler if i % 8 == 4 else int(buckets[i % len(buckets)])
        reqs.append((i, rng.integers(4, VOCAB, size=t).astype(np.int32),
                     int(rng.integers(MAX_NEW // 2, MAX_NEW + 1))))
    return reqs


def bench_paged_vs_dense(cfg, params, n_dense_slots: int = 2,
                         max_len: int = 96, block_size: int = 16):
    """Equal-memory comparison: N dense slots of ``max_len`` vs a paged pool
    of N*max_len/block_size blocks spread over 4N batch rows. Returns
    (concurrency, tok/s) per allocator over the same request stream."""
    n_req = 8 * n_dense_slots
    num_blocks = n_dense_slots * max_len // block_size

    def build(paged: bool) -> ContinuousBatcher:
        if paged:
            return ContinuousBatcher(params, cfg,
                                     batch_size=4 * n_dense_slots,
                                     max_len=max_len, paged=True,
                                     block_size=block_size,
                                     num_blocks=num_blocks)
        return ContinuousBatcher(params, cfg, batch_size=n_dense_slots,
                                 max_len=max_len)

    out = {}
    for paged in (False, True):
        batcher = build(paged)          # blocks/slots fully reclaim per run,
        concurrency, dt, done = 0, 0.0, []   # so one batcher serves both passes
        for warm in (True, False):
            for uid, prompt, mnt in _mixed_requests(n_req, max_len):
                batcher.submit(Request(uid=uid, prompt=prompt.copy(),
                                       max_new_tokens=mnt))
            if warm:
                batcher.run()           # compile every prefill/decode shape
                batcher.done.clear()
            else:
                t0 = time.perf_counter()
                concurrency = batcher.step()
                done = batcher.run()
                dt = time.perf_counter() - t0
        tok_s = sum(len(r.output) for r in done) / dt
        out["paged" if paged else "dense"] = (concurrency, tok_s)
    return out


def bench_prefill_interleave(cfg, params, long_len: int = 96,
                             budgets=(None, 48, 16)) -> list:
    """Decode-stall + time-to-first-token while a long prompt streams in.

    Request A decodes steadily; a long request B is then submitted. For
    each ``token_budget`` (None = one-shot-sized: the whole prompt in one
    chunk, i.e. the old admit-then-decode tick shape) we record every tick's
    wall time from B's submission until B's first generated token. Returns
    rows of (budget_label, max_tick_ms, median_tick_ms, ttft_ms): the max
    tick is the decode stall bound — the worst inter-token latency request
    A observes while B prefills."""
    max_len = long_len + MAX_NEW + 8
    rng = np.random.default_rng(0)
    long_prompt = rng.integers(4, VOCAB, size=long_len).astype(np.int32)
    short_prompt = rng.integers(4, VOCAB, size=PROMPT_LEN).astype(np.int32)
    rows = []
    for budget in budgets:
        tb = budget if budget is not None else max_len
        label = "one-shot" if budget is None else str(budget)
        # warm pass compiles every tick shape on the SAME batcher (the jit
        # cache is per-instance), timed pass measures
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=max_len,
                              token_budget=tb)
        # pass 0 warms the jit cache; the rows report pass 1's ticks/ttft
        # (the loop leaves the last pass's measurements bound)
        for pass_idx in range(2):
            uid_a, uid_b = 2 * pass_idx, 2 * pass_idx + 1
            b.submit(Request(uid=uid_a, prompt=short_prompt.copy(),
                             max_new_tokens=2 * MAX_NEW))
            for _ in range(3):
                b.step()                      # A reaches steady decode
            b.submit(Request(uid=uid_b, prompt=long_prompt.copy(),
                             max_new_tokens=MAX_NEW))
            t0 = time.perf_counter()
            ticks, ttft = [], None
            while ttft is None:
                ts = time.perf_counter()
                b.step()
                ticks.append(time.perf_counter() - ts)
                slot_b = next((s for s in b.slots
                               if s.req is not None and s.req.uid == uid_b),
                              None)
                done_b = any(r.uid == uid_b for r in b.done)
                if (slot_b is not None and slot_b.generated) or done_b:
                    ttft = time.perf_counter() - t0
            b.run()
        rows.append((label, 1e3 * max(ticks),
                     1e3 * sorted(ticks)[len(ticks) // 2], 1e3 * ttft))
    return rows


def _train_tiny(method: str, steps: int, vocab: int = 64, seq: int = 32):
    """Tiny 2-layer model trained on the synthetic Markov chain (decisive
    greedy margins — the agreement metric's precondition)."""
    import dataclasses

    from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import TrainTask, init_train_state, make_train_step

    cfg = opt_tiny(vocab=vocab, seq_len=seq)
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, n_heads=2,
                              n_kv_heads=2, d_head=32, d_ff=256)
    kw = {"alpha": 4.0} if method == "clipped_softmax" else {}
    cfg = apply_method(cfg, method, **kw)
    task = TrainTask(cfg=cfg, optimizer=AdamWConfig(lr=1e-3))
    data = SyntheticLM(SyntheticLMConfig(vocab_size=vocab, seq_len=seq,
                                         batch_size=32, seed=0, branching=8))
    state = init_train_state(jax.random.PRNGKey(0), task)
    step_fn = jax.jit(make_train_step(task), donate_argnums=(0,))
    for i in range(steps):
        state, _ = step_fn(state,
                           jax.tree_util.tree_map(jnp.asarray, data.batch(i)))
    return cfg, state.params, data


def bench_int8_vs_fp() -> None:
    from repro.models.transformer import paged_kv_block_bytes
    from repro.quant import QConfig

    steps = 40 if SMOKE else 400
    methods = ["clipped_softmax"] if SMOKE \
        else ["vanilla", "clipped_softmax", "gated_attention"]
    print("method,engine,tok_s,agreement_pct")
    cfg = params = None
    for method in methods:
        cfg, params, data = _train_tiny(method, steps)
        prompts = [data.batch(999)["tokens"][i][:12].astype(np.int32)
                   for i in range(6)]

        def serve(qconfig):
            b = ContinuousBatcher(params, cfg, batch_size=4, max_len=64,
                                  paged=True, block_size=8, qconfig=qconfig)
            outs, dt = {}, 0.0
            for warm in (True, False):
                for u, p in enumerate(prompts):
                    b.submit(Request(uid=u, prompt=p.copy(),
                                     max_new_tokens=MAX_NEW))
                t0 = time.perf_counter()
                done = b.run()
                dt = time.perf_counter() - t0
                outs = {r.uid: np.asarray(r.output) for r in done}
                b.done.clear()
            return outs, sum(len(o) for o in outs.values()) / dt

        fp_out, fp_tok_s = serve(None)
        q8_out, q8_tok_s = serve(QConfig())
        pairs = [(x, y) for u in fp_out
                 for x, y in zip(fp_out[u], q8_out[u])]
        agree = 100.0 * sum(x == y for x, y in pairs) / max(len(pairs), 1)
        print(f"{method},fp,{fp_tok_s:.1f},100.0")
        print(f"{method},int8,{q8_tok_s:.1f},{agree:.1f}")

    # equal-byte-pool capacity (last trained model; training is irrelevant
    # to admission — only pool geometry matters)
    bs = 8
    budget = 12 * paged_kv_block_bytes(cfg, bs, kv_int8=False)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(4, cfg.vocab_size, 25).astype(np.int32)
            for _ in range(8)]
    print("\n# int8 vs fp KV pool, equal byte budget "
          f"({budget} B/layer): peak concurrently-advancing rows")
    print("kv_cache,num_blocks,peak_rows")
    for kv_int8 in (False, True):
        nb = budget // paged_kv_block_bytes(cfg, bs, kv_int8=kv_int8)
        b = ContinuousBatcher(params, cfg, batch_size=8, max_len=32,
                              paged=True, block_size=bs, num_blocks=nb,
                              kv_int8=kv_int8)
        for u, p in enumerate(reqs):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=2))
        peak = 0
        while b.queue or any(s.req is not None for s in b.slots):
            b.step()
            peak = max(peak, sum(1 for s in b.slots if s.blocks))
        print(f"{'int8' if kv_int8 else 'fp'},{nb},{peak}")


def bench_open_loop_goodput() -> None:
    """Section 6: goodput vs offered load, fp vs int8-KV, virtual clock.
    Deterministic per seed — two runs of this section print identical
    numbers (the trace, the engine, and the tick-cost model all are)."""
    import dataclasses

    from repro.serving import (TickCostModel, WorkloadConfig,
                               generate_trace, run_workload)

    cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                              max_seq_len=160)
    params = model_init(jax.random.PRNGKey(0), cfg)
    rates = (30.0,) if SMOKE else (30.0, 120.0, 480.0)
    n_req = 12 if SMOKE else 48
    cost = TickCostModel()

    def engine(kv_int8):
        return ContinuousBatcher(params, cfg, batch_size=4, max_len=160,
                                 token_budget=64, prefill_budget=32,
                                 paged=True, block_size=8, num_blocks=48,
                                 kv_int8=kv_int8, swap_break_even_tokens=24,
                                 on_pool_exhausted="shed")

    print("engine,rate,goodput_tok,goodput_tok_s,delivered_tok,in_slo,"
          "offered,shed,stall_p99_ms")
    for rate in rates:
        trace = generate_trace(WorkloadConfig(
            seed=0, n_requests=n_req, rate=rate, prompt_max=64, out_max=16))
        for kv_int8 in (False, True):
            rep = run_workload(engine(kv_int8), trace, cost)
            in_slo = sum(t.in_slo for t in rep.tiers.values())
            shed = sum(sum(t.failed.values()) for t in rep.tiers.values())
            print(f"{'int8' if kv_int8 else 'fp'},{rate:.0f},"
                  f"{rep.goodput_tokens},{rep.goodput_tok_s:.1f},"
                  f"{rep.delivered_tokens},{in_slo},{len(trace)},{shed},"
                  f"{rep.stall_p99 * 1e3:.2f}")
        # per-tier detail at the highest rate (where tiers diverge)
        if rate == rates[-1]:
            print("# per-tier (fp engine, highest rate):")
            print(run_workload(engine(False), trace, cost).table())


def bench_prefix_sharing() -> None:
    """Section 7: prefix cache — cached vs cold TTFT in ticks, then
    equal-byte goodput with sharing off vs on at several overlap ratios.
    Deterministic: tick counts and the virtual-clock reports are exact."""
    import dataclasses

    from repro.serving import (TickCostModel, WorkloadConfig,
                               generate_trace, run_workload)

    cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                              max_seq_len=160)
    params = model_init(jax.random.PRNGKey(0), cfg)
    bs = 8

    def engine(share, **kw):
        base = dict(batch_size=4, max_len=160, token_budget=64,
                    prefill_budget=32, paged=True, block_size=bs,
                    num_blocks=48, swap_break_even_tokens=24,
                    on_pool_exhausted="shed", prefix_cache=share)
        base.update(kw)
        return ContinuousBatcher(params, cfg, **base)

    # --- TTFT: same prompt cold then cached, chunked at one block/tick.
    # Cold prefills every chunk; cached maps the trie blocks and feeds
    # only the tail, so its first token lands on the FIRST tick.
    prompt = (np.arange(5 * bs) % 50 + 4).astype(np.int32)
    b = engine(True, prefill_chunk=bs)

    def ticks_to_first(uid):
        b.submit(Request(uid=uid, prompt=prompt.copy(), max_new_tokens=4))
        n = 0
        while not any(s.generated for s in b.slots if s.req is not None):
            b.step()
            n += 1
        while b.queue or any(s.req is not None for s in b.slots):
            b.step()
        return n

    cold, warm = ticks_to_first(0), ticks_to_first(1)
    print("admission,ticks_to_first_token,prefill_tokens_run")
    print(f"cold,{cold},{len(prompt)}")
    print(f"cached,{warm},{len(prompt) - b.prefix_cache.tokens_reused}")

    # --- equal-byte open-loop sweep over prompt-overlap ratios
    fracs = (0.0, 0.9) if SMOKE else (0.0, 0.5, 0.9)
    n_req = 12 if SMOKE else 48
    cost = TickCostModel()
    print("overlap_frac,sharing,goodput_tok,delivered_tok,in_slo,shed,"
          "prefix_hits,tokens_reused,cow_copies")
    for frac in fracs:
        trace = generate_trace(WorkloadConfig(
            seed=0, n_requests=n_req, rate=120.0, prompt_max=32,
            out_max=16, prefix_len=3 * bs, prefix_frac=frac))
        for share in (False, True):
            e = engine(share)
            rep = run_workload(e, trace, cost)
            in_slo = sum(t.in_slo for t in rep.tiers.values())
            shed = sum(sum(t.failed.values()) for t in rep.tiers.values())
            pc = e.prefix_cache
            print(f"{frac:.1f},{'on' if share else 'off'},"
                  f"{rep.goodput_tokens},{rep.delivered_tokens},{in_slo},"
                  f"{shed},{pc.hits if pc else 0},"
                  f"{pc.tokens_reused if pc else 0},{e.cow_copies}")


def bench_spec_decode() -> None:
    """Section 8: speculative decoding — decode tok/s + accept rate vs
    draft length k, repetitive vs random prompts, fp vs int8-KV.

    The drafter is model-free n-gram lookup (``serving.speculate``), so
    the accept rate is a property of the token stream: repetitive
    prompts (and the tiny model's cyclic greedy continuations) accept
    most drafts, while random prompts mostly reject — bounding the
    overhead side. Verification is bitwise-lossless, so tok/s is the
    ONLY moving number: outputs are identical to the k=0 engine by
    construction (tests/test_spec_decode.py holds that line). Reported
    tok/s counts BANKED tokens over pure-decode ticks only (prefill
    excluded), i.e. the inter-token rate a client observes; speedup is
    vs the k=0 engine on the same trace. Results land in
    BENCH_spec_decode.json (non-smoke runs) so the perf trajectory is
    diffable across PRs."""
    import json

    from repro.models.transformer import ModelConfig
    from repro.serving import SpecConfig

    # a 2-layer toy whose greedy continuations settle into short cycles
    # within ~10 tokens: the CPU-scale stand-in for genuinely repetitive
    # decode streams (echo/extraction/templated output), where n-gram
    # drafting earns its keep. The random trace is the other extreme.
    cfg = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64, pos="rope",
                      max_seq_len=1024, scan_layers=False, remat=False,
                      mlp_kind="swiglu", norm="rmsnorm")
    params = model_init(jax.random.PRNGKey(0), cfg)
    max_new = MAX_NEW if SMOKE else 64
    plen = 24
    max_len = -(-(plen + max_new + 16) // 8) * 8  # block-size multiple
    n_req = 4 if SMOKE else 8
    ks = (0, 4) if SMOKE else (0, 2, 4, 8)
    engines = ("fp",) if SMOKE else ("fp", "int8")
    traces = ("repetitive",) if SMOKE else ("repetitive", "random")
    rng = np.random.default_rng(0)
    motifs = ((2, 9), (1, 2, 3), (13, 17), (10, 20, 30))
    prompts = {
        "repetitive": [np.asarray((list(motifs[u % len(motifs)]) * plen)
                                  [:plen], np.int32) for u in range(n_req)],
        "random": [rng.integers(4, cfg.vocab_size, plen).astype(np.int32)
                   for _ in range(n_req)],
    }

    def run_one(trace: str, engine: str, k: int):
        b = ContinuousBatcher(
            params, cfg, batch_size=4, max_len=max_len, paged=True,
            block_size=8, num_blocks=4 * (max_len // 8) + 8,
            kv_int8=(engine == "int8"),
            spec=SpecConfig(k=k) if k else None)
        banked, dt = 0, 0.0
        for warm in (True, False):
            for u, p in enumerate(prompts[trace]):
                b.submit(Request(uid=u, prompt=p.copy(),
                                 max_new_tokens=max_new))
            if warm:
                b.run()         # compile every tick shape on this engine
                b.done.clear()
                continue
            while b.queue or any(s.req is not None for s in b.slots):
                pure_decode = not b.queue and all(
                    s.prefill is None for s in b.slots if s.req is not None)
                t0 = time.perf_counter()
                b.step()
                if pure_decode:
                    dt += time.perf_counter() - t0
                    banked += b.last_tick_new_tokens
        rate = b.spec_accepted / max(b.spec_drafted, 1)
        return banked / max(dt, 1e-9), rate

    print("trace,engine,k,decode_tok_s,accept_rate,speedup_vs_k0")
    rows = []
    for trace in traces:
        for engine in engines:
            base_tok_s = None
            for k in ks:
                tok_s, rate = run_one(trace, engine, k)
                if k == 0:
                    base_tok_s = tok_s
                speedup = tok_s / base_tok_s
                print(f"{trace},{engine},{k},{tok_s:.1f},{rate:.2f},"
                      f"{speedup:.2f}")
                rows.append(dict(trace=trace, engine=engine, k=k,
                                 decode_tok_s=round(tok_s, 1),
                                 accept_rate=round(rate, 3),
                                 speedup_vs_k0=round(speedup, 2)))
    if not SMOKE:
        out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "BENCH_spec_decode.json")
        payload = {
            "meta": dict(model="tiny-2L-d32", vocab=cfg.vocab_size,
                         prompt_len=plen, max_new_tokens=max_new,
                         n_requests=n_req, batch_size=4, block_size=8,
                         backend=jax.default_backend(),
                         note="decode tok/s over pure-decode ticks, banked "
                              "tokens only (drafts are free compute, not "
                              "goodput). accept_rate = accepted/drafted for "
                              "the n-gram drafter; speedup vs the k=0 "
                              "engine on the same trace+engine. Outputs "
                              "are bitwise-identical across k by the "
                              "position-keyed acceptance rule."),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {os.path.relpath(out_path)}")


def main() -> None:
    print(f"decode throughput, max_new_tokens={MAX_NEW}, prompt={PROMPT_LEN}"
          + (" [--smoke]" if SMOKE else ""))
    print("method,batch,generate_tok_s,batcher_tok_s")
    for name, method, kwargs in METHODS:
        cfg, params = make(method, kwargs)
        for b in BATCHES:
            g = bench_generate(cfg, params, b, reps=1 if SMOKE else 3)
            s = bench_batcher(cfg, params, b)
            print(f"{name},{b},{g:.1f},{s:.1f}")

    print("\n# dense vs paged KV cache, equal pool memory "
          "(N dense slots == N*max_len/block_size blocks), mixed prompts")
    print("allocator,concurrent_requests,tok_s")
    cfg, params = make(None, {})
    for alloc, (conc, tok_s) in bench_paged_vs_dense(cfg, params).items():
        print(f"{alloc},{conc},{tok_s:.1f}")

    print("\n# prefill interleaving: long prompt admitted mid-decode "
          "(max tick = decode stall bound)")
    print("token_budget,max_tick_ms,median_tick_ms,ttft_ms")
    for label, mx, med, ttft in bench_prefill_interleave(
            cfg, params, long_len=32 if SMOKE else 96,
            budgets=(None, 16) if SMOKE else (None, 48, 16)):
        print(f"{label},{mx:.2f},{med:.2f},{ttft:.2f}")

    print("\n# int8 vs fp serving (W8A8 tick + int8 paged KV; "
          "trained tiny model — see module docstring)")
    bench_int8_vs_fp()

    print("\n# open-loop goodput under seeded traffic "
          "(virtual clock; goodput = tokens delivered inside SLO)")
    bench_open_loop_goodput()

    print("\n# prefix sharing: cached vs cold TTFT, then equal-byte "
          "goodput vs prompt-overlap ratio (sharing off/on)")
    bench_prefix_sharing()

    print("\n# speculative decoding: decode tok/s + accept rate vs draft "
          "length k (n-gram drafter; bitwise-lossless verification)")
    bench_spec_decode()


if __name__ == "__main__":
    main()
