"""Paper Table 10: low-bit PTQ sweep (W8A8 / W6A8 / W4A8 / W6A6) with
min-max vs MSE weight-range estimation, on a clipped-softmax-trained model
vs a vanilla one."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_steps, make_family, train_and_measure
from repro.configs import apply_method
from repro.models import model_apply
from repro.quant import QConfig, QuantContext, calibrate, evaluate_perplexity
from repro.train.losses import loss_for

SETTINGS = [
    ("W8A8/minmax", QConfig(weight_bits=8, act_bits=8)),
    ("W6A8/mse", QConfig(weight_bits=6, act_bits=8, weight_estimator="mse")),
    ("W4A8/mse", QConfig(weight_bits=4, act_bits=8, weight_estimator="mse")),
    ("W6A6/mse", QConfig(weight_bits=6, act_bits=6, weight_estimator="mse")),
]


def run(print_fn=print) -> None:
    cfg0, loss_kind = make_family("bert")
    print_fn("# Table 10 — low-bit PTQ sweep [BERT-family]")
    print_fn("method,setting,fp_ppl,q_ppl")
    for method, kw in (("vanilla", {}), ("clipped_softmax", {"alpha": 4.0})):
        cfg = apply_method(cfg0, method, **kw)
        r = train_and_measure(cfg, loss_kind, steps=bench_steps(0.75))
        params, data = r["params"], r["data"]

        def apply_fn(p, batch, ctx):
            logits, _ = model_apply(p, cfg, batch, ctx=ctx)
            return logits

        def loss_fn(p, batch, ctx):
            ctx = ctx if ctx is not None else QuantContext(None)
            logits, _ = model_apply(p, cfg, batch, ctx=ctx)
            return loss_for(loss_kind)(logits, jnp.asarray(batch["labels"]))

        for name, qc in SETTINGS:
            cal = [jax.tree_util.tree_map(jnp.asarray,
                                          data.batch(5_000_000 + i, loss_kind))
                   for i in range(8)]
            ctx = calibrate(apply_fn, params, cal, qc, 8)
            ev = [jax.tree_util.tree_map(jnp.asarray,
                                         data.batch(10_000_000 + i, loss_kind))
                  for i in range(4)]
            q = evaluate_perplexity(loss_fn, params, ev, ctx, 4)
            print_fn(f"{method},{name},{r['fp_ppl']:.3f},{q:.3f}")


if __name__ == "__main__":
    run()
