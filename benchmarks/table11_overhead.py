"""Paper Table 11: runtime overhead of clipped softmax / gated attention
vs vanilla pre-training (measured per train step; the paper reports 1-8%%
on A100 — we report the CPU-tiny equivalent plus the kernel-level numbers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import make_family
from repro.configs import apply_method
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.optim import AdamWConfig
from repro.train import TrainTask, init_train_state, make_train_step

METHODS = [("vanilla", {}), ("clipped_softmax", {"alpha": 4.0}),
           ("gated_attention", {"pi_init": 0.5}),
           ("gated_attention_mlp", {"pi_init": 0.5, "gate_kind": "mlp"})]


def _time_steps(cfg, loss_kind, n=12):
    task = TrainTask(cfg=cfg, loss_kind=loss_kind,
                     optimizer=AdamWConfig(lr=1e-3))
    state = init_train_state(jax.random.PRNGKey(0), task)
    step = jax.jit(make_train_step(task), donate_argnums=(0,))
    data = SyntheticLM(SyntheticLMConfig(vocab_size=cfg.vocab_size,
                                         seq_len=64, batch_size=16))
    batch = jax.tree_util.tree_map(jnp.asarray, data.batch(0, loss_kind))
    state, m = step(state, batch)        # compile
    m["loss"].block_until_ready()
    t0 = time.perf_counter()
    for i in range(n):
        state, m = step(state, batch)
    m["loss"].block_until_ready()
    return (time.perf_counter() - t0) / n


def run(print_fn=print) -> None:
    cfg0, loss_kind = make_family("bert")
    print_fn("# Table 11 — runtime overhead per train step [BERT-family]")
    print_fn("method,us_per_step,overhead_vs_vanilla_pct")
    base = None
    for name, kw in METHODS:
        method = "gated_attention" if name.startswith("gated") else name
        cfg = apply_method(cfg0, method, **kw)
        s = _time_steps(cfg, loss_kind)
        base = s if base is None else base
        print_fn(f"{name},{s*1e6:.0f},{(s/base-1)*100:.1f}")


if __name__ == "__main__":
    run()
