"""Paper Table 1: the impact of clipped-softmax stretch factors (gamma,
zeta) on FP ppl, outlier metrics and W8A8 ppl — BERT-family MLM protocol.

Paper finding to reproduce: gamma < 0 (exact zeros) does the work; zeta > 1
behaves like vanilla; combining adds nothing.
"""
from __future__ import annotations

from benchmarks.common import bench_steps, HEADER, fmt_row, make_family, train_and_measure
from repro.configs import apply_method

GRID = [
    ("vanilla(g=0,z=1)", 0.0, 1.0),
    ("g=0,z=1.03", 0.0, 1.03),
    ("g=-0.003,z=1", -0.003, 1.0),
    ("g=-0.03,z=1", -0.03, 1.0),
    ("g=-0.03,z=1.03", -0.03, 1.03),
]


def run(print_fn=print) -> None:
    cfg0, loss_kind = make_family("bert")
    print_fn("# Table 1 — clipped softmax (gamma, zeta) [BERT-family MLM]")
    print_fn(HEADER)
    for name, gamma, zeta in GRID:
        if gamma == 0.0 and zeta == 1.0:
            cfg = apply_method(cfg0, "vanilla")
        else:
            cfg = apply_method(cfg0, "clipped_softmax", gamma=gamma, zeta=zeta)
        r = train_and_measure(cfg, loss_kind, steps=bench_steps(0.5))
        print_fn(fmt_row(name, r))


if __name__ == "__main__":
    run()
