"""Paper Table 2 (main results): vanilla vs clipped softmax vs gated
attention on the BERT-family (MLM) and OPT-family (CLM) protocols.
Reports FP ppl / max inf-norm / kurtosis / W8A8 ppl per method."""
from __future__ import annotations

from benchmarks.common import HEADER, fmt_row, make_family, train_and_measure
from repro.configs import apply_method

METHODS = [
    ("vanilla", {}),
    ("clipped_softmax", {"alpha": 4.0}),
    ("gated_attention", {"pi_init": 0.5}),
]


def run(print_fn=print) -> None:
    for family in ("bert", "opt"):
        cfg0, loss_kind = make_family(family)
        print_fn(f"# Table 2 — main results [{family}-family {loss_kind}]")
        print_fn(HEADER)
        for method, kw in METHODS:
            cfg = apply_method(cfg0, method, **kw)
            r = train_and_measure(cfg, loss_kind)
            print_fn(fmt_row(f"{family}/{method}", r))


if __name__ == "__main__":
    run()
