"""Paper Table 4/5 ablation: gating-function parameterizations
(Linear / MLP / All-heads-linear) + their parameter overhead."""
from __future__ import annotations

from benchmarks.common import bench_steps, HEADER, fmt_row, make_family, train_and_measure
from repro.configs import apply_method
from repro.core.gating import GateConfig, gate_param_count

KINDS = ["linear", "mlp", "all_heads_linear"]


def run(print_fn=print) -> None:
    cfg0, loss_kind = make_family("bert")
    print_fn("# Table 4 — gating architectures [BERT-family]")
    print_fn("gate,extra_params," + HEADER.split(",", 1)[1])
    for kind in KINDS:
        cfg = apply_method(cfg0, "gated_attention", pi_init=0.5,
                           gate_kind=kind)
        extra = gate_param_count(GateConfig(kind, n_hid=4), cfg.n_heads,
                                 cfg.head_dim, cfg.d_model) * cfg.n_layers
        r = train_and_measure(cfg, loss_kind, steps=bench_steps(0.5))
        print_fn(f"{kind},{extra}," + fmt_row("", r).split(",", 1)[1])


if __name__ == "__main__":
    run()
