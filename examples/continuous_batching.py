"""Continuous-batching serving demo: requests of different lengths stream
through a fixed slot pool; finished slots refill from the queue without
draining the batch. Every active slot decodes on every tick at its own
position (per-row cache scatter) — no lockstep cohorts — and requests stop
early at EOS.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import apply_method
from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import ContinuousBatcher, Request

EOS_ID = 5          # synthetic EOS: some requests will emit it mid-stream


def main() -> None:
    cfg = apply_method(opt_tiny(vocab=256, seq_len=64), "clipped_softmax",
                       alpha=4.0)
    params = model_init(jax.random.PRNGKey(0), cfg)
    batcher = ContinuousBatcher(params, cfg, batch_size=4, max_len=64,
                                eos_id=EOS_ID)

    rng = np.random.default_rng(0)
    n_req = 10
    for i in range(n_req):
        batcher.submit(Request(
            uid=i,
            prompt=rng.integers(4, 256, size=int(rng.integers(4, 12))).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 10))))

    t0 = time.perf_counter()
    ticks = 0
    while batcher.queue or any(s.req for s in batcher.slots):
        active = batcher.step()
        ticks += 1
        if ticks % 5 == 0:
            print(f"tick {ticks:3d}: {active} active slots, "
                  f"{len(batcher.queue)} queued, {len(batcher.done)} done")
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in batcher.done)
    print(f"\nserved {len(batcher.done)}/{n_req} requests, "
          f"{total_tokens} tokens in {dt:.1f}s over {ticks} ticks "
          f"({total_tokens/dt:.1f} tok/s)")
    for r in sorted(batcher.done, key=lambda r: r.uid)[:3]:
        stop = "EOS" if len(r.output) and r.output[-1] == EOS_ID else "budget"
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output.tolist()} ({stop})")


if __name__ == "__main__":
    main()
