"""Reproduce the paper's Section 3 outlier analysis on a trained model:
outlier counts per hidden dimension / token position (Fig. 1), attention
concentration on low-information tokens, and the vanilla-vs-clipped
contrast.

    PYTHONPATH=src python examples/outlier_analysis.py --steps 400
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import apply_method
from repro.configs.paper_models import bert_tiny
from repro.core import outlier_counts_by_dim, outlier_counts_by_token
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import model_apply
from repro.optim import AdamWConfig
from repro.train import LoopConfig, TrainTask, run_training


def analyze(params, cfg, batch, label):
    _, aux = model_apply(params, cfg, batch, collect_acts=True)
    acts = aux["attn_outputs"]
    last = acts[-1]                                     # (B, T, D)
    by_dim = np.asarray(outlier_counts_by_dim(last))
    by_tok = np.asarray(outlier_counts_by_token(last))
    inf = float(jnp.max(jnp.abs(last)))
    print(f"\n[{label}] last-layer attention output:")
    print(f"  max |x|        : {inf:.2f}")
    print(f"  outliers (6s)  : {by_dim.sum()}")
    if by_dim.sum():
        top = np.argsort(by_dim)[-3:][::-1]
        print(f"  top hidden dims: {[(int(d), int(by_dim[d])) for d in top]}")
        ttop = np.argsort(by_tok)[-3:][::-1]
        print(f"  top token pos  : {[(int(t), int(by_tok[t])) for t in ttop]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    data = SyntheticLM(SyntheticLMConfig(vocab_size=512, seq_len=64,
                                         batch_size=16))
    batch = jax.tree_util.tree_map(jnp.asarray, data.batch(12345, "mlm"))

    for method in ("vanilla", "clipped_softmax"):
        cfg = apply_method(bert_tiny(vocab=512, seq_len=64), method, alpha=4.0)
        task = TrainTask(cfg=cfg, loss_kind="mlm",
                         optimizer=AdamWConfig(lr=2e-3))
        out = run_training(task, data, LoopConfig(
            total_steps=args.steps, eval_every=0, log_every=args.steps // 4),
            batch_kind="mlm")
        analyze(out["state"].params, cfg, batch, method)


if __name__ == "__main__":
    main()
