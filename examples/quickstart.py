"""Quickstart: build a quantizable transformer with the paper's two
modifications, run a forward pass, inspect outlier metrics, quantize.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import apply_method, get_arch, list_archs
from repro.configs.paper_models import opt_tiny
from repro.core import OutlierStats, clipped_softmax, infinity_norm, kurtosis
from repro.models import model_apply, model_init
from repro.quant import QConfig, QuantContext, calibrate


def main() -> None:
    print("Assigned architecture pool:", ", ".join(list_archs()))

    # 1. the paper's core op: exact zeros with finite logits
    logits = jnp.array([[0.0, 1.0, 6.0, 6.0]])
    print("\nclipped_softmax(gamma=-0.03):", clipped_softmax(logits, -0.03))

    # 2. any pool arch + any method, one switch
    cfg = apply_method(get_arch("qwen3-14b").smoke(), "gated_attention",
                       pi_init=0.5)
    params = model_init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32) * 5}
    out, aux = model_apply(params, cfg, batch, collect_acts=True)
    print(f"\n{cfg.name}: logits {out.shape}")

    # 3. the paper's outlier telemetry
    stats = OutlierStats()
    stats.update(aux["attn_outputs"])
    print("outlier metrics:", stats.summary())

    # 4. PTQ in three lines
    cfg2 = apply_method(opt_tiny(vocab=256, seq_len=32), "clipped_softmax",
                        alpha=4.0)
    p2 = model_init(jax.random.PRNGKey(1), cfg2)

    def apply_fn(p, b, ctx):
        return model_apply(p, cfg2, b, ctx=ctx)[0]

    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i),
                                             (4, 32), 0, 256)}
               for i in range(4)]
    ctx = calibrate(apply_fn, p2, batches, QConfig(), 4)
    q_logits = apply_fn(p2, batches[0], ctx)
    print(f"\nW8A8 simulated forward: {q_logits.shape}, "
          f"{len(ctx.ranges)} calibrated sites")


if __name__ == "__main__":
    main()
