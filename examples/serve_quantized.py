"""Serve a model with batched requests through the W8A8-simulated path:
prefill + decode with a calibrated QuantContext, plus the int8 MXU kernel
on the LM head as the hardware-exact reference.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import apply_method
from repro.configs.paper_models import opt_tiny
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.kernels import linear_w8a8, quantize_weights_int8
from repro.models import model_apply, model_init
from repro.quant import QConfig, calibrate
from repro.serving import GenerateConfig, generate


def main() -> None:
    cfg = apply_method(opt_tiny(vocab=512, seq_len=64), "clipped_softmax",
                       alpha=4.0)
    cfg = dataclasses.replace(cfg, max_seq_len=128)
    params = model_init(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(SyntheticLMConfig(vocab_size=512, seq_len=64,
                                         batch_size=4))

    # calibrate W8A8
    def apply_fn(p, b, ctx):
        return model_apply(p, cfg, b, ctx=ctx)[0]

    cal = [jax.tree_util.tree_map(jnp.asarray, data.batch(i)) for i in range(4)]
    ctx = calibrate(apply_fn, params, cal, QConfig(), 4)
    print(f"calibrated {len(ctx.ranges)} activation sites")

    # batched generation (FP path) — one fused jitted decode loop
    prompts = jnp.asarray(data.batch(99)["tokens"][:, :16])
    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, GenerateConfig(max_new_tokens=16))
    dt = time.perf_counter() - t0
    n_new = out.shape[0] * 16
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s batched, greedy)")

    # sampling path: temperature + top-k, early EOS with padding
    out_s = generate(params, cfg, prompts,
                     GenerateConfig(max_new_tokens=16, temperature=0.8,
                                    top_k=20, eos_id=5),
                     key=jax.random.PRNGKey(42))
    stopped = int((out_s[:, 16:] == 5).any(axis=1).sum())
    print(f"sampled top-k=20 T=0.8: {out_s.shape}, "
          f"{stopped}/{out_s.shape[0]} rows hit EOS early")

    # hardware-exact int8 matmul on the LM head (the op the paper's method
    # makes safe): compare against the float head
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    w = params["embed"]["table"].T  # tied head (d_model, vocab)
    wq, ws = quantize_weights_int8(w)
    y_int8 = linear_w8a8(x, wq, ws)
    y_fp = x @ w
    rel = float(jnp.mean(jnp.abs(y_int8 - y_fp)) / jnp.mean(jnp.abs(y_fp)))
    print(f"int8 MXU-path LM head vs fp: rel err {rel:.4f}")


if __name__ == "__main__":
    main()
