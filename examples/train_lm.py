"""End-to-end training driver: pre-train an LM with the paper's method,
with checkpointing, outlier telemetry and final PTQ — the paper's whole
experimental pipeline as one script.

    PYTHONPATH=src python examples/train_lm.py --method clipped_softmax \
        --steps 300 --arch opt-tiny
    PYTHONPATH=src python examples/train_lm.py --arch granite-moe-1b-a400m \
        --smoke --steps 50           # any pool arch (reduced config)
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import apply_method, get_arch
from repro.configs.paper_models import opt_tiny
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import model_apply
from repro.optim import AdamWConfig, linear_warmup_linear_decay
from repro.quant import QConfig, QuantContext, calibrate, evaluate_perplexity
from repro.train import LoopConfig, TrainTask, run_training
from repro.train.losses import loss_for


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-tiny")
    ap.add_argument("--method", default="clipped_softmax",
                    choices=["vanilla", "clipped_softmax", "gated_attention"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.arch == "opt-tiny":
        cfg = opt_tiny(vocab=512, seq_len=args.seq_len)
    else:
        spec = get_arch(args.arch)
        cfg = spec.smoke() if args.smoke else spec.full()
    cfg = apply_method(cfg, args.method, alpha=4.0, pi_init=0.5)
    loss_kind = "clm" if cfg.causal else "frames"

    task = TrainTask(
        cfg=cfg, loss_kind=loss_kind,
        optimizer=AdamWConfig(lr=args.lr),
        schedule=linear_warmup_linear_decay(args.steps // 10, args.steps))
    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch_size))

    print(f"== training {cfg.name} [{args.method}] for {args.steps} steps ==")
    out = run_training(task, data, LoopConfig(
        total_steps=args.steps, eval_every=max(args.steps // 4, 1),
        eval_batches=4, log_every=max(args.steps // 10, 1),
        ckpt_every=args.steps // 2 if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir), batch_kind=loss_kind)
    print(f"median step: {out['median_step_s']*1e3:.0f} ms, "
          f"stragglers: {out['stragglers']}")

    # ---- the paper's PTQ epilogue ----
    params = out["state"].params

    def apply_fn(p, b, ctx):
        return model_apply(p, cfg, b, ctx=ctx)[0]

    def loss_fn(p, b, ctx):
        ctx = ctx if ctx is not None else QuantContext(None)
        logits, _ = model_apply(p, cfg, b, ctx=ctx)
        return loss_for(loss_kind)(logits, jnp.asarray(b["labels"]))

    cal = [jax.tree_util.tree_map(jnp.asarray, data.batch(10_000 + i, loss_kind))
           for i in range(8)]
    ctx = calibrate(apply_fn, params, cal, QConfig(), 8)
    fp = evaluate_perplexity(loss_fn, params, cal, None, 4)
    q8 = evaluate_perplexity(loss_fn, params, cal, ctx, 4)
    print(f"FP ppl {fp:.3f} -> W8A8 ppl {q8:.3f} "
          f"(gap {100 * (q8 / fp - 1):.2f}%)")


if __name__ == "__main__":
    main()
