"""repro: Quantizable Transformers (NeurIPS 2023) as a multi-pod JAX
framework. See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
