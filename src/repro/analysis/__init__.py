"""Static analysis + runtime guards for the repro serving contracts.

* ``repro.analysis.lint`` — AST contract linter
  (``python -m repro.analysis.lint src/``), rules R001–R005; see
  ``docs/contracts.md`` for the contracts and the suppression syntax.
* ``repro.analysis.compile_guard`` — pytest plugin counting jax.jit
  compilations per test (``@pytest.mark.compile_budget(n)``), the runtime
  tripwire for recompile regressions the linter cannot prove statically.
"""
from repro.analysis.engine import (Finding, LintContext, Rule, SourceFile,
                                   default_rules, render_json, render_text,
                                   run_lint)
from repro.analysis.rules import ALL_RULES

__all__ = [
    "ALL_RULES", "Finding", "LintContext", "Rule", "SourceFile",
    "default_rules", "render_json", "render_text", "run_lint",
]
