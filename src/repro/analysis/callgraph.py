"""Lightweight jit-seeded call graph over the linted files.

R001 (host-sync) must know whether a function can execute *inside* a
traced region. Rather than a full interprocedural analysis, this builds
the cheap approximation that is exact for this repo's idioms:

  * **Seeds** — functions handed to ``jax.jit`` (decorator form,
    ``partial(jax.jit, ...)`` decorator form, or a ``jax.jit(fn, ...)``
    call whose first argument resolves to a known function) and kernels
    handed to ``pl.pallas_call``.
  * **Edges** — inside a function body, every ``Name`` that resolves to a
    function visible in scope (enclosing defs, module-level defs, or a
    ``from repro.x import fn`` / ``import repro.x as m`` + ``m.fn``
    import) adds an edge. Resolving *references* rather than just direct
    calls keeps closure-passing idioms (``jax.lax.scan(body, ...)``,
    ``jax.vmap(per_group)``, ``jax.checkpoint(group_apply)``) in the
    graph for free.
  * **Reachable** — the closure of the seeds over those edges. A function
    is "jit-reachable" if tracing can enter it; host-side drivers (the
    scheduler's slot bookkeeping, PTQ calibration loops) that merely
    *call* jitted functions are not.

Known blind spot, by design: method calls through objects
(``self.x(...)``, ``ctx.act(...)``) are not resolved — the repo's traced
regions are plain functions, and resolving attribute calls would need
type inference for little gain here.

``JitSite`` records every ``jax.jit`` call with its parsed
``static_argnums``/``static_argnames`` and the name the wrapper is bound
to (``train_step = jax.jit(...)`` / ``self._step_fn = jax.jit(...)``), so
R002 can match later call sites of the jitted wrapper against its static
positions.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.engine import SourceFile


def dotted(node: ast.AST) -> Optional[str]:
    """Textual dotted path of a Name/Attribute chain ('jax.jit'), else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def literal_int_tuple(node: Optional[ast.AST]) -> Tuple[int, ...]:
    if node is None:
        return ()
    try:
        v = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ()
    if isinstance(v, int):
        return (v,)
    if isinstance(v, (tuple, list)):
        return tuple(x for x in v if isinstance(x, int))
    return ()


def literal_str_tuple(node: Optional[ast.AST]) -> Tuple[str, ...]:
    if node is None:
        return ()
    try:
        v = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return ()
    if isinstance(v, str):
        return (v,)
    if isinstance(v, (tuple, list)):
        return tuple(x for x in v if isinstance(x, str))
    return ()


@dataclasses.dataclass
class FunctionInfo:
    key: str                       # "module:qualname"
    module: str
    qualname: str
    node: ast.AST                  # FunctionDef / AsyncFunctionDef / Lambda
    refs: Set[str] = dataclasses.field(default_factory=set)
    seed: Optional[str] = None     # None | "jit" | "pallas"


@dataclasses.dataclass
class JitSite:
    module: str
    call: ast.Call                 # the jax.jit(...) call
    fn_key: Optional[str]          # resolved key of the wrapped function
    static_argnums: Tuple[int, ...]
    static_argnames: Tuple[str, ...]
    bound_to: Optional[str] = None  # 'name' / 'self.attr' the wrapper binds


class _ModuleWalker(ast.NodeVisitor):
    """Collect functions, import aliases, references and jit/pallas seeds
    of one module, with lexical scoping for nested defs."""

    def __init__(self, src: SourceFile, graph: "CallGraph"):
        self.src = src
        self.graph = graph
        self.module = src.module
        # import alias tables
        self.mod_alias: Dict[str, str] = {}    # local name -> module path
        self.sym_alias: Dict[str, str] = {}    # local name -> "module:sym"
        # scope stack: list of {local fn name -> key}
        self.scopes: List[Dict[str, str]] = [{}]
        self.qual: List[str] = []
        self.fn_stack: List[FunctionInfo] = []
        self._prescan_imports(src.tree)
        self._collect(src.tree)

    # -- imports ---------------------------------------------------------
    def _prescan_imports(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.mod_alias[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.sym_alias[a.asname or a.name] = (
                        f"{node.module}:{a.name}")

    # -- collection ------------------------------------------------------
    def _collect(self, node: ast.AST) -> None:
        """Two passes per scope body: register defs first (so forward
        references and mutual recursion resolve), then walk bodies."""
        body = node.body if hasattr(node, "body") else []
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._register(stmt)
        for stmt in body:
            self.visit(stmt)

    def _register(self, node: ast.AST, name: Optional[str] = None) -> str:
        name = name or node.name
        qual = ".".join(self.qual + [name])
        key = f"{self.module}:{qual}"
        if key not in self.graph.functions:
            self.graph.functions[key] = FunctionInfo(
                key=key, module=self.module, qualname=qual, node=node)
        self.scopes[-1][name] = key
        return key

    def resolve(self, name: str) -> Optional[str]:
        """Resolve a bare name to a function key through the scope stack,
        then through ``from x import f`` aliases."""
        for scope in reversed(self.scopes):
            if name in scope:
                return scope[name]
        return self.sym_alias.get(name)

    def resolve_dotted(self, text: str) -> Optional[str]:
        """Resolve 'alias.attr' where alias is an imported module."""
        if "." not in text:
            return self.resolve(text)
        root, rest = text.split(".", 1)
        mod = self.mod_alias.get(root)
        if mod is not None and "." not in rest:
            return f"{mod}:{rest}"
        sym = self.sym_alias.get(root)
        if sym is not None and "." not in rest:
            # from repro import serving; serving.decode.fn — out of scope
            return None
        return None

    def _canonical(self, text: Optional[str]) -> Optional[str]:
        """Expand the leading import alias of a dotted path ('pl.pallas_call'
        -> 'jax.experimental.pallas.pallas_call')."""
        if not text:
            return text
        root, _, rest = text.partition(".")
        mod = self.mod_alias.get(root)
        if mod and rest:
            return f"{mod}.{rest}"
        sym = self.sym_alias.get(root)
        if sym and not rest:
            return sym.replace(":", ".")
        return text

    # -- visitors --------------------------------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_def(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_def(node)

    def _visit_def(self, node) -> None:
        key = self.scopes[-1].get(node.name) or self._register(node)
        info = self.graph.functions[key]
        if self._jit_decorated(node):
            info.seed = "jit"
            self.graph.jit_sites.append(JitSite(
                module=self.module, call=None, fn_key=key,
                static_argnums=self._deco_static(node, "static_argnums"),
                static_argnames=self._deco_static(node, "static_argnames",
                                                  names=True),
                bound_to=node.name))
        for d in node.decorator_list:
            self.visit(d)
        self.qual.append(node.name)
        self.scopes.append({})
        self.fn_stack.append(info)
        self._collect(node)
        self.fn_stack.pop()
        self.scopes.pop()
        self.qual.pop()

    def _jit_decorated(self, node) -> bool:
        for d in node.decorator_list:
            text = self._canonical(dotted(d if not isinstance(d, ast.Call)
                                          else d.func))
            if text == "jax.jit":
                return True
            if isinstance(d, ast.Call) and text in (
                    "functools.partial", "partial") and d.args:
                if self._canonical(dotted(d.args[0])) == "jax.jit":
                    return True
        return False

    def _deco_static(self, node, kw: str, names: bool = False):
        for d in node.decorator_list:
            if isinstance(d, ast.Call):
                for k in d.keywords:
                    if k.arg == kw:
                        return (literal_str_tuple(k.value) if names
                                else literal_int_tuple(k.value))
        return ()

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # lambdas participate as anonymous functions of the enclosing scope
        if self.fn_stack:
            self._refs_from(node.body)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load) and self.fn_stack:
            key = self.resolve(node.id)
            if key is not None:
                self.fn_stack[-1].refs.add(key)

    def _refs_from(self, node: ast.AST) -> None:
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                key = self.resolve(n.id)
                if key is not None:
                    self.fn_stack[-1].refs.add(key)

    def visit_Call(self, node: ast.Call) -> None:
        text = self._canonical(dotted(node.func))
        if text == "jax.jit":
            fn_key = None
            if node.args:
                arg_text = dotted(node.args[0])
                if arg_text is not None:
                    fn_key = (self.resolve(arg_text) if "." not in arg_text
                              else self.resolve_dotted(arg_text))
            kws = {k.arg: k.value for k in node.keywords}
            site = JitSite(
                module=self.module, call=node, fn_key=fn_key,
                static_argnums=literal_int_tuple(kws.get("static_argnums")),
                static_argnames=literal_str_tuple(kws.get("static_argnames")))
            self.graph.jit_sites.append(site)
            if fn_key is not None and fn_key in self.graph.functions:
                self.graph.functions[fn_key].seed = "jit"
        elif text is not None and text.endswith("pallas_call") and node.args:
            arg_text = dotted(node.args[0])
            fn_key = self.resolve(arg_text) if arg_text else None
            if fn_key is None and isinstance(node.args[0], ast.Call):
                # functools.partial(_kernel, cfg=...) wrapping the kernel
                inner = node.args[0]
                if inner.args:
                    t = dotted(inner.args[0])
                    fn_key = self.resolve(t) if t else None
            if fn_key is not None and fn_key in self.graph.functions:
                self.graph.functions[fn_key].seed = "pallas"
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # visit children first so visit_Call has registered the JitSite,
        # then record `name = jax.jit(...)` / `self.x = jax.jit(...)`
        self.generic_visit(node)
        if isinstance(node.value, ast.Call) and \
                self._canonical(dotted(node.value.func)) == "jax.jit":
            target = dotted(node.targets[0]) if node.targets else None
            for site in reversed(self.graph.jit_sites):
                if site.call is node.value:
                    site.bound_to = target
                    break


class CallGraph:
    """Build once per lint run; exposes jit-reachability and jit sites."""

    def __init__(self, files: Sequence[SourceFile]):
        self.functions: Dict[str, FunctionInfo] = {}
        self.jit_sites: List[JitSite] = []
        self.walkers: Dict[str, _ModuleWalker] = {}
        for src in files:
            self.walkers[src.module] = _ModuleWalker(src, self)
        self.reachable: Set[str] = self._closure()
        self._reachable_nodes = {
            id(self.functions[k].node) for k in self.reachable}

    def _closure(self) -> Set[str]:
        seen: Set[str] = set()
        frontier = [k for k, f in self.functions.items() if f.seed]
        while frontier:
            k = frontier.pop()
            if k in seen or k not in self.functions:
                continue
            seen.add(k)
            frontier.extend(self.functions[k].refs - seen)
        return seen

    # ------------------------------------------------------------------
    def is_reachable(self, node: ast.AST) -> bool:
        """True if this FunctionDef node can execute under tracing."""
        return id(node) in self._reachable_nodes

    def seed_of(self, node: ast.AST) -> Optional[str]:
        for f in self.functions.values():
            if f.node is node:
                return f.seed
        return None

    def sites_in(self, module: str) -> List[JitSite]:
        return [s for s in self.jit_sites if s.module == module]

    def function(self, key: str) -> Optional[FunctionInfo]:
        return self.functions.get(key)
