"""Runtime recompile tripwire: count jax.jit compilations per test.

The static pass (R002) proves bucketing *syntactically*; this guard
proves it *operationally* — a test sweeps the decode tick across live
widths / chunk sizes and asserts the number of compiled specializations
stays within the pow-2 bucket budget. Any change that lets a raw
runtime-varying value reach a static arg or a shape shows up as a
compile-count explosion and fails the test.

Mechanism: ``jax.jit`` wrappers expose ``_cache_size()`` (the number of
compiled variants held by the pjit cache). ``install()`` monkeypatches
``jax.jit`` so every wrapper created afterwards is tracked in a
``WeakSet``; ``CompileGuard`` snapshots the aggregate cache size on entry
and reports the delta. Wrappers created *before* ``install()`` (module
import time) are still countable by passing them explicitly via
``track``.

pytest integration (wired in ``tests/conftest.py``)::

    @pytest.mark.compile_budget(6)
    def test_decode_tick_sweep(...):
        ...

fails with ``CompileBudgetExceeded`` if the test body compiles more than
6 jit specializations. Tests without the marker are unaffected.
"""
from __future__ import annotations

import weakref
from typing import Iterable, List, Optional

import jax

_tracked: "weakref.WeakSet" = weakref.WeakSet()
_orig_jit = None


def install() -> None:
    """Monkeypatch ``jax.jit`` so new wrappers are tracked. Idempotent."""
    global _orig_jit
    if _orig_jit is not None:
        return
    _orig_jit = jax.jit

    def _tracking_jit(*args, **kwargs):
        wrapped = _orig_jit(*args, **kwargs)
        try:
            _tracked.add(wrapped)
        except TypeError:  # non-weakrefable wrapper: skip tracking
            pass
        return wrapped

    jax.jit = _tracking_jit


def uninstall() -> None:
    global _orig_jit
    if _orig_jit is not None:
        jax.jit = _orig_jit
        _orig_jit = None


def _cache_size(fn) -> int:
    try:
        return fn._cache_size()
    except Exception:  # noqa: BLE001 - wrapper died mid-read; count as 0
        return 0


def track(fn) -> None:
    """Explicitly track a jit wrapper created before ``install()``."""
    try:
        _tracked.add(fn)
    except TypeError:
        pass


class CompileBudgetExceeded(AssertionError):
    pass


class CompileGuard:
    """Context manager measuring jit compilations within its scope.

    >>> with CompileGuard(budget=4) as guard:
    ...     for w in (1, 2, 3, 5, 8):
    ...         step(x, _bucket(w))
    >>> guard.compiles  # ≤ 4: buckets 1, 2, 4, 8
    """

    def __init__(self, budget: Optional[int] = None,
                 extra: Iterable = ()) -> None:
        self.budget = budget
        self._extra: List = list(extra)
        self._baseline = 0
        self.compiles = 0

    def _wrappers(self) -> List:
        seen = set()
        out = []
        for fn in list(_tracked) + self._extra:
            if id(fn) not in seen:
                seen.add(id(fn))
                out.append(fn)
        return out

    def _total(self) -> int:
        return sum(_cache_size(fn) for fn in self._wrappers())

    def __enter__(self) -> "CompileGuard":
        install()
        self._baseline = self._total()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.compiles = self._total() - self._baseline
        if exc_type is None and self.budget is not None and \
                self.compiles > self.budget:
            raise CompileBudgetExceeded(
                f"compiled {self.compiles} jit specializations, budget is "
                f"{self.budget} — a static arg or shape is varying per "
                f"call instead of being pow-2 bucketed")


# ==========================================================================
# pytest plugin
# ==========================================================================
def pytest_configure(config) -> None:
    config.addinivalue_line(
        "markers",
        "compile_budget(n): fail the test if its body compiles more than "
        "n jax.jit specializations (recompile-regression tripwire)")
    install()


def make_autouse_fixture(pytest):
    """Build the autouse fixture enforcing ``compile_budget`` markers;
    called from tests/conftest.py with the pytest module."""

    @pytest.fixture(autouse=True)
    def _compile_budget_guard(request):
        marker = request.node.get_closest_marker("compile_budget")
        if marker is None:
            yield
            return
        budget = marker.args[0] if marker.args else None
        with CompileGuard(budget=budget) as guard:
            yield
        request.node.user_properties.append(
            ("jit_compiles", guard.compiles))

    return _compile_budget_guard
