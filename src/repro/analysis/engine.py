"""Rule engine of the repro contract linter.

The serving stack is correct only by convention: masked-scatter cache
writes, ``fold_in(seed, position)`` RNG keying, pow-2 bucketed static args
on the paged read path, tracer-free Pallas ``index_map`` closures. Those
conventions live in docstrings and review comments — this package turns
them into machine-checked rules (see ``rules.py`` for the catalogue and
``docs/contracts.md`` for the contracts each rule encodes).

This module is the rule-agnostic machinery:

  * ``SourceFile`` — parsed file (AST + per-line suppression comments);
  * ``Finding`` — one diagnostic, with an optional ``fixit`` suggestion;
  * ``Rule`` — base class; rules yield findings from (file, context);
  * ``LintContext`` — project-wide state shared by rules (every parsed
    file plus the jit/pallas call graph from ``callgraph.py``);
  * ``run_lint`` — drive rules over files, apply suppressions, report.

Suppression syntax (the only sanctioned way to silence a true-but-
intentional violation)::

    t_step = int(counts.max())  # repro: ignore[R002] exact length required

A suppression must name the rule id and carry a non-empty reason; a
reasonless ``# repro: ignore[R00x]`` does NOT suppress — the finding stays
and an R000 diagnostic is added, so "silenced without justification" can
never pass CI. A suppression comment on its own line applies to the next
statement; one at end-of-line applies to the statement covering that line.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore\[([A-Za-z0-9,\s]+)\]\s*(.*?)\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic. ``line``/``end_line`` delimit the statement the
    suppression scanner searches for ``# repro: ignore[...]`` comments."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    fixit: Optional[str] = None
    end_line: Optional[int] = None
    suppressed: bool = False
    suppress_reason: Optional[str] = None

    def to_json(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        if d["end_line"] is None:
            d["end_line"] = d["line"]
        return d


class SourceFile:
    """A parsed source file plus its suppression table."""

    def __init__(self, path: str, text: str, module: Optional[str] = None):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.module = module if module is not None else module_name(path)
        # line -> {rule_id -> reason}; "" reason marks an invalid suppression
        self.suppressions: Dict[int, Dict[str, str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = [r.strip().upper() for r in m.group(1).split(",")]
            reason = m.group(2).strip()
            table = self.suppressions.setdefault(i, {})
            for r in rules:
                if r:
                    table[r] = reason

    # ------------------------------------------------------------------
    def suppression_for(self, rule: str, line: int,
                        end_line: Optional[int] = None) -> Optional[str]:
        """Reason string if ``rule`` is suppressed anywhere on the
        statement's lines or the line directly above it; None otherwise.
        An empty reason is NOT a valid suppression (returns None)."""
        lo, hi = line, end_line if end_line is not None else line
        for ln in range(max(lo - 1, 1), hi + 1):
            reason = self.suppressions.get(ln, {}).get(rule)
            if reason:
                return reason
        return None

    def has_reasonless_suppression(self, rule: str, line: int,
                                   end_line: Optional[int] = None) -> bool:
        lo, hi = line, end_line if end_line is not None else line
        for ln in range(max(lo - 1, 1), hi + 1):
            if self.suppressions.get(ln, {}).get(rule) == "":
                return True
        return False


def module_name(path: str) -> str:
    """Dotted module name of ``path``, rooted at the last ``src/`` (or the
    first ``repro`` component) so call-graph edges can be resolved through
    absolute ``repro.*`` imports."""
    parts = path.replace("\\", "/").split("/")
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    for anchor in ("src", "repro"):
        if anchor in parts:
            i = parts.index(anchor)
            parts = parts[i + 1 :] if anchor == "src" else parts[i:]
            break
    return ".".join(p for p in parts if p) or parts[-1]


class LintContext:
    """Project-wide state shared by every rule: all parsed files plus the
    jit/pallas call graph (built lazily on first access)."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)
        self.by_module: Dict[str, SourceFile] = {f.module: f for f in files}
        self._graph = None

    @property
    def graph(self):
        if self._graph is None:
            from repro.analysis.callgraph import CallGraph
            self._graph = CallGraph(self.files)
        return self._graph


class Rule:
    """Base class. Subclasses set ``id``/``title``/``contract`` and yield
    ``Finding`` objects from ``check``."""

    id: str = "R000"
    title: str = ""
    # one-line statement of the repo contract the rule enforces
    contract: str = ""

    def check(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        raise NotImplementedError

    # helper: build a finding anchored at an AST node
    def finding(self, src: SourceFile, node: ast.AST, message: str,
                fixit: Optional[str] = None) -> Finding:
        return Finding(
            rule=self.id, path=src.path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            end_line=getattr(node, "end_lineno", None),
            message=message, fixit=fixit)


def default_rules() -> List[Rule]:
    from repro.analysis.rules import ALL_RULES
    return [cls() for cls in ALL_RULES]


def run_lint(sources: Iterable[Tuple[str, str]],
             rules: Optional[Sequence[Rule]] = None,
             ) -> Tuple[List[Finding], LintContext]:
    """Lint ``(path, text)`` pairs. Returns (findings, context): every
    finding, with ``suppressed``/``suppress_reason`` filled in, sorted by
    (path, line, rule). Reasonless suppressions surface as R000 findings."""
    files: List[SourceFile] = []
    findings: List[Finding] = []
    for path, text in sources:
        try:
            files.append(SourceFile(path, text))
        except SyntaxError as e:
            findings.append(Finding(
                rule="R000", path=path, line=e.lineno or 1, col=e.offset or 0,
                message=f"syntax error: {e.msg}"))
    ctx = LintContext(files)
    rules = list(rules) if rules is not None else default_rules()
    for src in files:
        for rule in rules:
            for f in rule.check(src, ctx):
                reason = src.suppression_for(f.rule, f.line, f.end_line)
                if reason is not None:
                    f = dataclasses.replace(
                        f, suppressed=True, suppress_reason=reason)
                elif src.has_reasonless_suppression(f.rule, f.line, f.end_line):
                    findings.append(Finding(
                        rule="R000", path=src.path, line=f.line, col=f.col,
                        message=(f"suppression of {f.rule} has no reason — "
                                 f"add one: # repro: ignore[{f.rule}] <why>")))
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.col))
    return findings, ctx


# ==========================================================================
# Reporters
# ==========================================================================
def render_text(findings: Sequence[Finding],
                show_suppressed: bool = False) -> str:
    out = []
    shown = 0
    for f in findings:
        if f.suppressed and not show_suppressed:
            continue
        shown += 1
        tag = " (suppressed: %s)" % f.suppress_reason if f.suppressed else ""
        out.append(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}{tag}")
        if f.fixit and not f.suppressed:
            out.append(f"    fix: {f.fixit}")
    active = sum(1 for f in findings if not f.suppressed)
    sup = len(findings) - active
    out.append(f"{active} finding(s), {sup} suppressed")
    return "\n".join(out)


def render_json(findings: Sequence[Finding],
                rules: Optional[Sequence[Rule]] = None) -> str:
    doc = {
        "findings": [f.to_json() for f in findings],
        "active": sum(1 for f in findings if not f.suppressed),
        "suppressed": sum(1 for f in findings if f.suppressed),
    }
    if rules is not None:
        doc["rules"] = [
            {"id": r.id, "title": r.title, "contract": r.contract}
            for r in rules]
    return json.dumps(doc, indent=2, sort_keys=True)
