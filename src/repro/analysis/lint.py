"""CLI driver: ``python -m repro.analysis.lint src/ [--format json]``.

Exit codes: 0 — clean (possibly with reasoned suppressions); 1 — at least
one active (unsuppressed) finding; 2 — usage / IO error.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Iterator, List, Tuple

from repro.analysis.engine import (default_rules, render_json, render_text,
                                   run_lint)

_SKIP_DIRS = {".git", "__pycache__", ".venv", "node_modules", "build",
              "dist", ".mypy_cache", ".ruff_cache"}


def iter_py_files(paths: List[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        else:
            raise FileNotFoundError(p)


def load_sources(paths: List[str]) -> List[Tuple[str, str]]:
    out = []
    for path in iter_py_files(paths):
        with open(path, "r", encoding="utf-8") as fh:
            out.append((path, fh.read()))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX/Pallas contract linter for the repro serving stack")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="include suppressed findings in text output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id}  {r.title}\n      contract: {r.contract}")
        return 0
    if args.rules:
        wanted = {r.strip().upper() for r in args.rules.split(",")}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            print(f"unknown rule id(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    try:
        sources = load_sources(args.paths)
    except FileNotFoundError as e:
        print(f"no such file or directory: {e}", file=sys.stderr)
        return 2
    if not sources:
        print("no python files found", file=sys.stderr)
        return 2

    findings, _ = run_lint(sources, rules=rules)
    if args.format == "json":
        print(render_json(findings, rules=rules))
    else:
        print(render_text(findings, show_suppressed=args.show_suppressed))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
