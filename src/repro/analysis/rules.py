"""The JAX/Pallas contract rules (R001–R005).

Each rule encodes one convention this repo's serving stack depends on;
``docs/contracts.md`` states the contracts in prose, the rule docstrings
state the exact detection heuristic (all of them are intentionally
*lightweight*: single-pass, syntactic + local taint, no type inference —
cheap enough to run on every push, precise enough that the current tree
lints clean with a handful of reasoned suppressions).

Shared machinery: a local taint analysis. A function's "tainted" names
start at its parameters (minus ones whose annotation marks them as
non-traced python scalars/configs) and flow through assignments;
``.shape`` / ``.dtype`` / ``len()`` access *kills* taint, because shapes
are static python values under tracing. R001 and R005 both ride on it.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.callgraph import dotted
from repro.analysis.engine import Finding, LintContext, Rule, SourceFile

# annotations that mark a parameter as a non-traced python value: static
# scalars, config dataclasses, strings. Anything else (or no annotation)
# is conservatively assumed traced.
_UNTRACED_ANN_RE = re.compile(r"\b(int|float|bool|str)\b|Config\b")
# attribute reads that produce static python values from traced arrays
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "weak_type"}
# calls whose *result* is a host python value (the call itself may still
# be a violation — R001 checks that separately)
_UNTAINT_CALLS = {"int", "float", "bool", "str", "len", "isinstance",
                  "hasattr", "getattr", "range", "type", "repr"}


def iter_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _all_args(fn: ast.FunctionDef) -> List[ast.arg]:
    a = fn.args
    out = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    if a.vararg:
        out.append(a.vararg)
    if a.kwarg:
        out.append(a.kwarg)
    return out


def param_taint(fn: ast.FunctionDef) -> Set[str]:
    """Initial tainted-name set: parameters that may hold traced values."""
    tainted: Set[str] = set()
    for arg in _all_args(fn):
        if arg.arg in ("self", "cls"):
            continue
        if arg.annotation is not None:
            ann = ast.unparse(arg.annotation)
            if _UNTRACED_ANN_RE.search(ann) and "Array" not in ann:
                continue
        tainted.add(arg.arg)
    return tainted


def is_tainted(node: ast.AST, tainted: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _SHAPE_ATTRS:
            return False
        return is_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        fname = dotted(node.func)
        if fname in _UNTAINT_CALLS:
            return False
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("item", "tolist"):
            return False
        if isinstance(node.func, ast.Attribute) and \
                is_tainted(node.func.value, tainted):
            return True          # method on a traced value -> traced
        return any(is_tainted(a, tainted) for a in node.args) or \
            any(is_tainted(k.value, tainted) for k in node.keywords)
    if isinstance(node, (ast.Constant, ast.Lambda)):
        return False
    return any(is_tainted(c, tainted) for c in ast.iter_child_nodes(node))


def _assign_targets(node: ast.AST) -> List[str]:
    names: List[str] = []
    targets = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        targets = [node.target]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                names.append(n.id)
    return names


def walk_statements(fn: ast.FunctionDef, tainted: Set[str], on_stmt) -> None:
    """Source-order statement walk with taint propagation. ``on_stmt`` is
    called with (stmt, tainted) *before* the statement's own assignment
    effects apply. Nested function bodies are skipped (they are analyzed
    as functions in their own right)."""

    def walk(body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            on_stmt(stmt, tainted)
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                value = stmt.value
                if value is not None:
                    t = is_tainted(value, tainted)
                    for name in _assign_targets(stmt):
                        (tainted.add if t else tainted.discard)(name)
            elif isinstance(stmt, ast.AugAssign):
                if is_tainted(stmt.value, tainted):
                    for name in _assign_targets(stmt):
                        tainted.add(name)
            elif isinstance(stmt, ast.For):
                t = is_tainted(stmt.iter, tainted)
                for n in ast.walk(stmt.target):
                    if isinstance(n, ast.Name):
                        (tainted.add if t else tainted.discard)(n.id)
                walk(stmt.body)
                walk(stmt.orelse)
            elif isinstance(stmt, ast.While):
                walk(stmt.body)
                walk(stmt.orelse)
            elif isinstance(stmt, ast.If):
                walk(stmt.body)
                walk(stmt.orelse)
            elif isinstance(stmt, ast.With):
                walk(stmt.body)
            elif isinstance(stmt, ast.Try):
                walk(stmt.body)
                for h in stmt.handlers:
                    walk(h.body)
                walk(stmt.orelse)
                walk(stmt.finalbody)

    walk(fn.body)


def _stmt_exprs(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Expression subtrees of one statement, excluding nested suites (those
    are walked as their own statements) and nested function bodies."""
    if isinstance(stmt, ast.Assign):
        yield stmt.value
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        if stmt.value is not None:
            yield stmt.value
    elif isinstance(stmt, (ast.Expr, ast.Return)):
        if stmt.value is not None:
            yield stmt.value
    elif isinstance(stmt, (ast.If, ast.While)):
        yield stmt.test
    elif isinstance(stmt, ast.For):
        yield stmt.iter
    elif isinstance(stmt, ast.With):
        for item in stmt.items:
            yield item.context_expr
    elif isinstance(stmt, ast.Assert):
        yield stmt.test
    elif isinstance(stmt, ast.Raise):
        if stmt.exc is not None:
            yield stmt.exc


# ==========================================================================
# R001 — host sync inside jit-reachable code
# ==========================================================================
class HostSyncRule(Rule):
    """``int()``/``float()``/``bool()``/``.item()``/``.tolist()``/
    ``np.asarray()``/``jax.device_get()`` applied to a value that flows
    from a traced argument, inside a function reachable from a ``jax.jit``
    or ``pallas_call`` seed. Under tracing these either fail
    (``TracerConversionError``) or, worse, silently bake a traced value
    into a constant; in host code they are fine — which is exactly why the
    rule is scoped by the call graph instead of firing on every cast."""

    id = "R001"
    title = "host sync in jit-reachable code"
    contract = ("jit-reachable code must keep traced values traced: no "
                "int()/float()/.item()/np.asarray on values flowing from "
                "traced args")

    _CASTS = {"int", "float", "bool", "complex"}
    _ATTRS = {"item", "tolist"}
    _NP_FNS = {"asarray", "array", "copy", "ascontiguousarray"}

    def check(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        graph = ctx.graph
        walker = graph.walkers.get(src.module)
        np_aliases = {"numpy"} | {
            a for a, m in (walker.mod_alias.items() if walker else ())
            if m == "numpy"}
        for fn in iter_functions(src.tree):
            if not graph.is_reachable(fn):
                continue
            yield from self._check_fn(src, fn, np_aliases)

    def _check_fn(self, src: SourceFile, fn: ast.FunctionDef,
                  np_aliases: Set[str]) -> Iterator[Finding]:
        found: List[Finding] = []

        def on_stmt(stmt: ast.stmt, tainted: Set[str]) -> None:
            for expr in _stmt_exprs(stmt):
                for call in ast.walk(expr):
                    if not isinstance(call, ast.Call):
                        continue
                    msg = self._violation(call, tainted, np_aliases)
                    if msg:
                        found.append(self.finding(
                            src, call,
                            f"{msg} in jit-reachable `{fn.name}`",
                            fixit=("keep the value traced (jnp ops / "
                                   "lax.cond) or hoist the sync into host "
                                   "code outside the jitted region")))

        walk_statements(fn, param_taint(fn), on_stmt)
        yield from found

    def _violation(self, call: ast.Call, tainted: Set[str],
                   np_aliases: Set[str]) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Name) and f.id in self._CASTS:
            if any(is_tainted(a, tainted) for a in call.args):
                return (f"`{f.id}()` forces a device sync on traced value "
                        f"`{ast.unparse(call.args[0])}`")
        if isinstance(f, ast.Attribute) and f.attr in self._ATTRS and \
                is_tainted(f.value, tainted):
            return (f"`.{f.attr}()` forces a device sync on traced value "
                    f"`{ast.unparse(f.value)}`")
        name = dotted(f) or ""
        parts = name.split(".")
        if len(parts) == 2 and parts[0] in np_aliases and \
                parts[1] in self._NP_FNS:
            if any(is_tainted(a, tainted) for a in call.args):
                return (f"`{name}()` materializes traced value "
                        f"`{ast.unparse(call.args[0])}` on host")
        if name == "jax.device_get" and \
                any(is_tainted(a, tainted) for a in call.args):
            return "`jax.device_get` on a traced value"
        return None


# ==========================================================================
# R002 — jit static-arg hygiene
# ==========================================================================
def _last_name(fname: str) -> str:
    return fname.split(".")[-1].lower()


def _has_reduction(node: ast.AST) -> bool:
    """Does the subtree read a scalar out of runtime data (``x.max()``,
    ``np.max(x)``) — the signature of a per-tick-varying python int?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            fname = dotted(n.func) or ""
            if isinstance(n.func, ast.Attribute) and \
                    n.func.attr in ("max", "min", "sum", "argmax", "item"):
                return True
            if "." in fname and _last_name(fname) in ("max", "min", "sum"):
                return True
    return False


def _raw_runtime_ints(expr: ast.AST) -> Iterator[ast.Call]:
    """``int(<reduction>)`` calls not already inside a ``*bucket*`` call."""

    def rec(node: ast.AST, bucketed: bool) -> Iterator[ast.Call]:
        if isinstance(node, ast.Call):
            fname = dotted(node.func) or ""
            if "bucket" in _last_name(fname):
                bucketed = True
            if not bucketed and isinstance(node.func, ast.Name) and \
                    node.func.id == "int" and \
                    any(_has_reduction(a) for a in node.args):
                yield node
        for child in ast.iter_child_nodes(node):
            yield from rec(child, bucketed)

    yield from rec(expr, False)


class StaticArgHygieneRule(Rule):
    """Two jit-recompilation hazards:

    (a) a locally-resolvable jitted function whose parameter is annotated
        with a python type (``int``/``bool``/``str`` or a ``*Config``
        dataclass) but is not declared in ``static_argnums``/
        ``static_argnames`` — configs fail hashing at trace time, python
        scalars silently retrace per value;
    (b) a per-tick-varying python int (``int(x.max())`` and friends) that
        feeds a static argument of a jitted callable or an array *shape*
        without passing through a ``*bucket*`` function — the unbounded-
        recompile class of the scheduler's ``t_step``/``live_width``
        plumbing (one compile per distinct runtime value instead of
        O(log) pow-2 buckets)."""

    id = "R002"
    title = "jit static-arg hygiene"
    contract = ("python-typed jit params must be static, and runtime-"
                "varying static args / shapes must be pow-2 bucketed")

    _STATIC_ANN_RE = re.compile(r"\b(int|bool|str)\b|Config\b")
    _SHAPE_CTORS = {"zeros", "ones", "full", "empty"}

    def check(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        graph = ctx.graph
        sites = graph.sites_in(src.module)
        yield from self._check_undeclared_static(src, graph, sites)
        yield from self._check_unbucketed(src, sites)

    # -- (a) -------------------------------------------------------------
    def _check_undeclared_static(self, src, graph, sites) -> Iterator[Finding]:
        for site in sites:
            info = graph.function(site.fn_key) if site.fn_key else None
            if info is None or info.module != src.module or \
                    not isinstance(info.node,
                                   (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            fn = info.node
            pos_args = list(fn.args.posonlyargs) + list(fn.args.args)
            for i, arg in enumerate(pos_args):
                if arg.arg in ("self", "cls") or arg.annotation is None:
                    continue
                ann = ast.unparse(arg.annotation)
                if "Array" in ann or not self._STATIC_ANN_RE.search(ann):
                    continue
                if i in site.static_argnums or \
                        arg.arg in site.static_argnames:
                    continue
                anchor = site.call if site.call is not None else fn
                yield self.finding(
                    src, anchor,
                    f"jit of `{fn.name}`: param `{arg.arg}: {ann}` is a "
                    f"python value but is not declared static",
                    fixit=(f"add static_argnums={i} (or static_argnames="
                           f"'{arg.arg}') to the jax.jit call"))

    # -- (b) -------------------------------------------------------------
    def _check_unbucketed(self, src, sites) -> Iterator[Finding]:
        bound: Dict[str, object] = {
            s.bound_to: s for s in sites
            if s.bound_to and (s.static_argnums or s.static_argnames)}
        for fn in iter_functions(src.tree):
            yield from self._check_fn(src, fn, bound)

    def _check_fn(self, src, fn: ast.FunctionDef, bound) -> Iterator[Finding]:
        raw_names: Dict[str, ast.stmt] = {}
        reported: Set[int] = set()

        def names_in(node: ast.AST) -> Set[str]:
            return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}

        def raw_in(node: ast.AST) -> Optional[ast.stmt]:
            """The statement to blame if ``node`` carries a raw runtime
            int: the direct expression, or the assignment that produced a
            name used inside it."""
            for c in _raw_runtime_ints(node):
                return c
            for name in names_in(node) & raw_names.keys():
                return raw_names[name]
            return None

        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and stmt.value is not None:
                if any(True for _ in _raw_runtime_ints(stmt.value)):
                    for name in _assign_targets(stmt):
                        raw_names[name] = stmt
            if not isinstance(stmt, (ast.Assign, ast.Expr, ast.Return,
                                     ast.AugAssign)):
                continue
            value = getattr(stmt, "value", None)
            if value is None:
                continue
            for call in ast.walk(value):
                if not isinstance(call, ast.Call):
                    continue
                fname = dotted(call.func) or ""
                # shape construction: np/jnp.{zeros,ones,full,empty}
                if "." in fname and \
                        _last_name(fname) in self._SHAPE_CTORS and call.args:
                    blame = raw_in(call.args[0])
                    if blame is not None and id(blame) not in reported:
                        reported.add(id(blame))
                        yield self.finding(
                            src, blame,
                            "runtime-varying int feeds an array shape "
                            f"(`{ast.unparse(call)[:60]}`) without "
                            "bucketing — one jit specialization per "
                            "distinct value",
                            fixit="round it up through a pow-2 bucketing "
                                  "helper (e.g. `_bucket(...)`) so at most "
                                  "O(log n) shapes exist")
                # static-arg positions of a known jitted wrapper
                site = bound.get(fname)
                if site is not None:
                    for i in site.static_argnums:
                        if i < len(call.args):
                            blame = raw_in(call.args[i])
                            if blame is not None and id(blame) not in reported:
                                reported.add(id(blame))
                                yield self.finding(
                                    src, blame,
                                    f"runtime-varying int feeds static arg "
                                    f"{i} of jitted `{fname}` without "
                                    "bucketing — one compile per distinct "
                                    "value",
                                    fixit="pass the value through a pow-2 "
                                          "bucketing helper before the "
                                          "static position")


# ==========================================================================
# R003 — masked-scatter contract on cache writes
# ==========================================================================
class MaskedScatterRule(Rule):
    """In ``models/``/``serving/``, any ``.at[...].set(...)`` /
    ``.add(...)`` into a KV cache or block pool must follow the
    masked-scatter convention: indices routed through ``jnp.where`` (dead
    rows / padding tokens redirected out of bounds) and ``mode="drop"`` on
    the write. Without both, a dead or stalled row's cache is clobbered —
    the exact class of bug the per-row decode engine was built to avoid
    (see ``model_apply``'s contract docstring)."""

    id = "R003"
    title = "masked-scatter cache-write contract"
    contract = ("cache/pool scatter writes must mask dead rows: "
                "jnp.where-guarded indices + mode='drop'")

    _CACHEISH_RE = re.compile(r"cache|pool|\bkv\b", re.IGNORECASE)

    def check(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        if not src.module.startswith(("repro.models", "repro.serving")) and \
                "/models/" not in src.path and "/serving/" not in src.path:
            return
        for fn in iter_functions(src.tree):
            guarded = self._where_assigned(fn)
            for call in ast.walk(fn):
                f = self._scatter_write(call)
                if f is None:
                    continue
                base, idx = f
                if not self._CACHEISH_RE.search(ast.unparse(base)):
                    continue
                mode = next((k.value for k in call.keywords
                             if k.arg == "mode"), None)
                has_drop = isinstance(mode, ast.Constant) and \
                    mode.value == "drop"
                has_guard = self._index_guarded(idx, guarded)
                if has_drop and has_guard:
                    continue
                missing = []
                if not has_guard:
                    missing.append("indices are not routed through a "
                                   "jnp.where mask")
                if not has_drop:
                    missing.append('mode="drop" is missing')
                yield self.finding(
                    src, call,
                    f"unguarded cache write `{ast.unparse(base)[:40]}"
                    f".at[...].{call.func.attr}`: " + " and ".join(missing),
                    fixit=('redirect dead entries out of bounds — idx = '
                           'jnp.where(active, idx, OOB) — and write with '
                           '.at[idx].set(v, mode="drop")'))

    @staticmethod
    def _scatter_write(node: ast.AST):
        """Match ``BASE.at[IDX].set/add(...)``; return (BASE, IDX)."""
        if not (isinstance(node, ast.Call) and
                isinstance(node.func, ast.Attribute) and
                node.func.attr in ("set", "add")):
            return None
        sub = node.func.value
        if not (isinstance(sub, ast.Subscript) and
                isinstance(sub.value, ast.Attribute) and
                sub.value.attr == "at"):
            return None
        return sub.value.value, sub.slice

    @staticmethod
    def _where_assigned(fn: ast.FunctionDef) -> Set[str]:
        names: Set[str] = set()
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and \
                    isinstance(stmt.value, ast.Call):
                fname = dotted(stmt.value.func) or ""
                if _last_name(fname) == "where":
                    names.update(_assign_targets(stmt))
        return names

    @staticmethod
    def _index_guarded(idx: ast.AST, guarded: Set[str]) -> bool:
        for n in ast.walk(idx):
            if isinstance(n, ast.Name) and n.id in guarded:
                return True
            if isinstance(n, ast.Call) and \
                    _last_name(dotted(n.func) or "") == "where":
                return True
        return False


# ==========================================================================
# R004 — PRNG key discipline
# ==========================================================================
class PrngReuseRule(Rule):
    """A PRNG key consumed by two ``jax.random.*`` draws without an
    interleaving ``split``/``fold_in`` produces *correlated* samples — the
    serving stack's slot/batch/backend-invariant sampling depends on every
    draw being keyed exactly once (``fold_in(request_key, position)``).
    Also flags a draw inside a loop whose body never re-derives the key:
    every iteration would sample the same stream."""

    id = "R004"
    title = "PRNG key reuse"
    contract = ("a key feeds exactly one jax.random draw; derive fresh "
                "keys with split/fold_in (position-keyed in serving)")

    _DRAWS = {"normal", "uniform", "categorical", "bernoulli", "randint",
              "truncated_normal", "gumbel", "permutation", "choice",
              "exponential", "laplace", "gamma", "beta", "poisson",
              "dirichlet", "bits", "ball", "rademacher"}
    _DERIVE = {"split", "fold_in", "PRNGKey", "key", "clone"}

    def check(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        for fn in iter_functions(src.tree):
            yield from self._check_fn(src, fn)

    def _is_draw(self, call: ast.Call) -> bool:
        fname = dotted(call.func) or ""
        parts = fname.split(".")
        return len(parts) >= 2 and parts[-2] == "random" and \
            parts[-1] in self._DRAWS

    def _is_derive(self, node: ast.AST) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Call) and \
                    _last_name(dotted(n.func) or "") in self._DERIVE:
                return True
        return False

    def _check_fn(self, src: SourceFile,
                  fn: ast.FunctionDef) -> Iterator[Finding]:
        draws: List[Tuple[int, str, ast.Call]] = []
        rebinds: Dict[str, List[int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue
            if isinstance(node, ast.Call) and self._is_draw(node) and \
                    node.args and isinstance(node.args[0], ast.Name):
                draws.append((node.lineno, node.args[0].id, node))
            if isinstance(node, ast.Assign) and node.value is not None and \
                    self._is_derive(node.value):
                for name in _assign_targets(node):
                    rebinds.setdefault(name, []).append(node.lineno)

        # straight-line double consumption
        draws.sort()
        last_use: Dict[str, int] = {}
        for line, name, node in draws:
            prev = last_use.get(name)
            if prev is not None and not any(
                    prev < ln <= line for ln in rebinds.get(name, [])):
                yield self.finding(
                    src, node,
                    f"key `{name}` already consumed by a jax.random draw "
                    f"at line {prev} and reused without split/fold_in — "
                    "the two draws are correlated",
                    fixit=f"derive a fresh key first: `{name}, sub = "
                          f"jax.random.split({name})` (or fold_in a "
                          "position for serving-invariant sampling)")
            last_use[name] = line

        # draw inside a loop with no per-iteration derivation
        for loop in ast.walk(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            lo, hi = loop.lineno, loop.end_lineno or loop.lineno
            loop_targets = set()
            if isinstance(loop, ast.For):
                loop_targets = {n.id for n in ast.walk(loop.target)
                                if isinstance(n, ast.Name)}
            for line, name, node in draws:
                if not (lo <= line <= hi) or name in loop_targets:
                    continue
                if not any(lo <= ln <= hi for ln in rebinds.get(name, [])):
                    yield self.finding(
                        src, node,
                        f"key `{name}` is drawn from inside a loop but "
                        "never re-derived per iteration — every iteration "
                        "samples the same stream",
                        fixit=f"fold the loop index in: `k = jax.random."
                              f"fold_in({name}, i)` before the draw")


# ==========================================================================
# R005 — Pallas kernel rules
# ==========================================================================
class PallasKernelRule(Rule):
    """Two Pallas-specific hazards in ``kernels/``:

    (a) a ``BlockSpec`` ``index_map`` that closes over a traced value —
        index maps run at *grid-planning* time on python/SMEM values; a
        captured tracer either fails lowering or silently constant-folds
        a stale value into the DMA addressing (the block-table kernels
        must route runtime tables through scalar prefetch instead);
    (b) a ref indexed with a python-dynamic slice (``ref[a:b]`` with
        non-constant bounds) — Mosaic needs static slice extents; dynamic
        offsets must go through ``pl.ds``/``pl.dynamic_slice``."""

    id = "R005"
    title = "Pallas index_map / ref-indexing rules"
    contract = ("index_map closures capture only shape-derived python "
                "values; refs are sliced statically or via pl.ds")

    def check(self, src: SourceFile, ctx: LintContext) -> Iterator[Finding]:
        walker = ctx.graph.walkers.get(src.module)
        imports_pallas = walker is not None and any(
            "pallas" in m for m in list(walker.mod_alias.values()) +
            [s.split(":")[0] for s in walker.sym_alias.values()])
        if not imports_pallas and "/kernels/" not in src.path:
            return
        for fn in iter_functions(src.tree):
            yield from self._check_index_maps(src, fn)
            yield from self._check_ref_slices(src, fn)

    # -- (a) index_map purity -------------------------------------------
    def _check_index_maps(self, src: SourceFile,
                          fn: ast.FunctionDef) -> Iterator[Finding]:
        specs = [c for c in ast.walk(fn)
                 if isinstance(c, ast.Call) and
                 (dotted(c.func) or "").endswith("BlockSpec")]
        if not specs:
            return
        # taint at function scope: array-ish params flowing through
        # assignments; .shape access kills taint
        tainted = param_taint(fn)
        walk_statements(fn, tainted, lambda s, t: None)
        local_defs = {f.name: f for f in ast.walk(fn)
                      if isinstance(f, ast.FunctionDef)}
        for spec in specs:
            imap = None
            if len(spec.args) >= 2:
                imap = spec.args[1]
            for k in spec.keywords:
                if k.arg == "index_map":
                    imap = k.value
            if imap is None:
                continue
            if isinstance(imap, ast.Name) and imap.id in local_defs:
                target = local_defs[imap.id]
                own = {a.arg for a in _all_args(target)}
                body = target
            elif isinstance(imap, ast.Lambda):
                own = {a.arg for a in _all_args(imap)}
                body = imap.body
            else:
                continue
            for n in ast.walk(body):
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                        and n.id not in own and n.id in tainted:
                    yield self.finding(
                        src, spec,
                        f"BlockSpec index_map closes over `{n.id}`, which "
                        "flows from a traced array — index maps must only "
                        "capture shape-derived python values",
                        fixit="pass runtime tables via scalar prefetch "
                              "(PrefetchScalarGridSpec) and read them as "
                              "index_map ref arguments instead")
                    break

    # -- (b) python-dynamic ref slices ----------------------------------
    def _check_ref_slices(self, src: SourceFile,
                          fn: ast.FunctionDef) -> Iterator[Finding]:
        if not any(a.arg.endswith(("_ref", "_scr"))
                   for a in _all_args(fn)):
            return
        for sub in ast.walk(fn):
            if not (isinstance(sub, ast.Subscript) and
                    isinstance(sub.value, ast.Name) and
                    sub.value.id.endswith(("_ref", "_scr"))):
                continue
            elts = sub.slice.elts if isinstance(sub.slice, ast.Tuple) \
                else [sub.slice]
            for e in elts:
                if isinstance(e, ast.Slice) and not (
                        self._static_bound(e.lower) and
                        self._static_bound(e.upper)):
                    yield self.finding(
                        src, sub,
                        f"ref `{sub.value.id}` sliced with python-dynamic "
                        f"bounds `{ast.unparse(e)}` — Mosaic needs static "
                        "slice extents",
                        fixit="use pl.ds(start, static_size) / "
                              "pl.dynamic_slice for dynamic offsets")

    @staticmethod
    def _static_bound(node: Optional[ast.AST]) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.UnaryOp) and \
                isinstance(node.operand, ast.Constant):
            return True
        return False


ALL_RULES = [HostSyncRule, StaticArgHygieneRule, MaskedScatterRule,
             PrngReuseRule, PallasKernelRule]
