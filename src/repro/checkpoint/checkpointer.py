"""Fault-tolerant checkpointing (no orbax in this environment).

Design points for preemptible 1000+-node fleets:
  * **atomic commit** — write to ``step_XXXXXXXX.tmp/``, fsync, then rename;
    a crash mid-save never corrupts the latest checkpoint;
  * **manifest** — JSON with step, param paths, shapes, dtypes; restore
    validates structure before touching the model;
  * **keep-k GC** — old checkpoints garbage-collected after a successful
    commit (never before);
  * **elastic restore** — tensors are stored *logically unsharded* (gathered
    per host), so a job may resume on a different device count / mesh; the
    trainer re-shards on the first jit call;
  * **deterministic resume** — the data pipeline is stateless (batch i is a
    pure function of seed+i), so resuming only needs the step counter.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import flatten_params

MANIFEST = "manifest.json"


def _tree_paths(tree: Any) -> List[Tuple[str, Any]]:
    return list(flatten_params(tree))


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Atomically write `tree` (any pytree of arrays) for `step`."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    entries = []
    arrays: Dict[str, np.ndarray] = {}
    for path, leaf in _tree_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        key = path.replace("/", ".")
        arrays[key] = arr
        entries.append({"path": path, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump({"step": step, "entries": entries}, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory)
        if re.fullmatch(r"step_\d{8}", d)
    )
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(directory)
        if re.fullmatch(r"step_\d{8}", d)
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (values replaced).

    Validates the manifest against the template's flattened paths; raises
    on mismatch (protects against restoring the wrong arch config).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    stored = {e["path"]: e for e in manifest["entries"]}
    tpl_paths = _tree_paths(template)
    if set(stored) != {p for p, _ in tpl_paths}:
        missing = {p for p, _ in tpl_paths} - set(stored)
        extra = set(stored) - {p for p, _ in tpl_paths}
        raise ValueError(f"checkpoint/template mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves = []
    for p, tpl_leaf in tpl_paths:
        arr = data[p.replace("/", ".")]
        if list(arr.shape) != list(tpl_leaf.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} vs "
                             f"template {tpl_leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=tpl_leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
