"""Architecture config registry (assigned pool + paper's own models)."""
from repro.configs.base import (
    SHAPES,
    ArchSpec,
    ShapeSpec,
    apply_method,
    cache_specs,
    get_arch,
    input_specs,
    list_archs,
    to_bf16,
)

__all__ = [
    "SHAPES", "ArchSpec", "ShapeSpec", "apply_method", "cache_specs",
    "get_arch", "input_specs", "list_archs", "to_bf16",
]
