"""Config registry: assigned architectures × input shapes.

Each arch module defines ``full()`` (the exact published config) and
``smoke()`` (a reduced same-family config for CPU tests), registered via
``register``. ``input_specs`` builds ShapeDtypeStruct stand-ins for every
(arch × shape) cell — shardable, weak-type-correct, zero allocation — which
the multi-pod dry-run lowers.

The paper's technique is selected per-run with ``method``:
    "vanilla" | "clipped_softmax" | "gated_attention"
applied uniformly to every softmax-attention block of any arch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gating import GateConfig
from repro.core.softmax import ClippedSoftmaxConfig
from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                          # moe | dense | vlm | hybrid | ssm | audio
    full: Callable[..., ModelConfig]     # full() -> published config
    smoke: Callable[..., ModelConfig]    # smoke() -> reduced config
    # shapes this arch skips, with the reason (documented in DESIGN.md)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()
    source: str = ""

    def skipped(self, shape: str) -> Optional[str]:
        for s, why in self.skip_shapes:
            if s == shape:
                return why
        return None


_REGISTRY: Dict[str, ArchSpec] = {}

SKIP_LONG = ("long_500k",
             "full softmax attention is quadratic; 500k decode reserved for "
             "sub-quadratic archs per assignment")
SKIP_DECODE_ENC = ("decode_32k", "encoder-only architecture has no autoregressive step")
SKIP_LONG_ENC = ("long_500k", "encoder-only architecture has no autoregressive step")


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import arch modules for registration side-effects
    from repro.configs import (  # noqa: F401
        granite_moe_1b_a400m,
        qwen2_moe_a2_7b,
        phi_3_vision_4_2b,
        deepseek_67b,
        gemma2_27b,
        qwen3_14b,
        codeqwen1_5_7b,
        recurrentgemma_9b,
        xlstm_1_3b,
        hubert_xlarge,
        paper_models,
    )


def apply_method(cfg: ModelConfig, method: str,
                 gamma: float = -0.03, alpha: Optional[float] = None,
                 zeta: float = 1.0, pi_init: float = 0.5,
                 gate_kind: str = "linear") -> ModelConfig:
    """Inject the paper's technique into any ModelConfig."""
    if method == "vanilla":
        return dataclasses.replace(
            cfg, softmax_cfg=ClippedSoftmaxConfig(), gate_cfg=GateConfig(kind="none"))
    if method == "clipped_softmax":
        sm = ClippedSoftmaxConfig(gamma=gamma, zeta=zeta, alpha=alpha)
        return dataclasses.replace(cfg, softmax_cfg=sm, gate_cfg=GateConfig(kind="none"))
    if method == "gated_attention":
        return dataclasses.replace(
            cfg, softmax_cfg=ClippedSoftmaxConfig(),
            gate_cfg=GateConfig.from_pi_init(pi_init, gate_kind))
    raise ValueError(f"unknown method {method!r}")


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell. Decode cells additionally need the cache
    spec — see ``cache_specs``."""
    b, t = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.step == "train":
        if cfg.input_kind == "tokens":
            return {"tokens": sds((b, t), jnp.int32), "labels": sds((b, t), jnp.int32)}
        if cfg.input_kind == "embeds":
            return {
                "embeds": sds((b, t, cfg.frontend_dim or cfg.d_model), jnp.float32),
                "labels": sds((b, t), jnp.int32),
            }
        # mixed (vlm): image-patch prefix + text tokens
        n_img = cfg.n_prefix_embeds
        return {
            "embeds": sds((b, n_img, cfg.d_model), jnp.float32),
            "tokens": sds((b, t - n_img), jnp.int32),
            "labels": sds((b, t), jnp.int32),
        }
    if shape.step == "prefill":
        if cfg.input_kind == "tokens":
            return {"tokens": sds((b, t), jnp.int32)}
        if cfg.input_kind == "embeds":
            return {"embeds": sds((b, t, cfg.frontend_dim or cfg.d_model), jnp.float32)}
        n_img = cfg.n_prefix_embeds
        return {
            "embeds": sds((b, n_img, cfg.d_model), jnp.float32),
            "tokens": sds((b, t - n_img), jnp.int32),
        }
    # decode: one new token against a seq_len cache
    return {"tokens": sds((b, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct pytree of the decode cache (via eval_shape)."""
    from repro.models.transformer import init_cache

    cfg_sized = dataclasses.replace(cfg, max_seq_len=max(shape.seq_len, cfg.window or 0))
    return jax.eval_shape(
        lambda: init_cache(cfg_sized, shape.global_batch, shape.seq_len,
                           dtype=cfg.compute_dtype)
    )


def to_bf16(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)
