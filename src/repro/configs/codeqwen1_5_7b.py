"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 architecture.

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab_size=92416, d_head=128,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
        tie_embeddings=False,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="codeqwen-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=128, d_head=16,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope",
        tie_embeddings=False, scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="codeqwen1.5-7b", family="dense", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),
    source="hf:Qwen/CodeQwen1.5-7B",
))
