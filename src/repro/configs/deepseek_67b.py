"""deepseek-67b [arXiv:2401.02954] — llama-architecture dense model.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=102400, d_head=128,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope", rope_theta=10000.0,
        tie_embeddings=False,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=160, vocab_size=128, d_head=8,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope",
        tie_embeddings=False, scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="deepseek-67b", family="dense", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),
    source="arXiv:2401.02954",
))
