"""gemma2-27b [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, alternating
local (window 4096) / global attention, logit soft-capping (attn 50,
final 30), sandwich (pre+post) RMSNorms, GeGLU, scaled embeddings.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
        d_ff=36864, vocab_size=256000, d_head=128,
        pattern=("local_attn", "attn"), window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_block_norm=True, embed_scale=True,
        mlp_kind="geglu", norm="rmsnorm", pos="rope", rope_theta=10000.0,
        tie_embeddings=True,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=128, d_head=16,   # d_head*H != d_model, like real
        pattern=("local_attn", "attn"), window=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_block_norm=True, embed_scale=True,
        mlp_kind="geglu", norm="rmsnorm", pos="rope",
        scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="gemma2-27b", family="dense", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),   # global layers are still quadratic
    source="arXiv:2408.00118",
))
