"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155; MoE: 32 experts, top-8,
per-expert d_ff=512.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig
from repro.nn.moe import MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab_size=49155, d_head=64,
        pattern=("attn",),
        moe=MoEConfig(n_experts=32, top_k=8, d_ff=512,
                      capacity_factor=1.25, group_size=4096),
        mlp_kind="swiglu", norm="rmsnorm", pos="rope", rope_theta=10000.0,
        tie_embeddings=True,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab_size=128, d_head=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=2.0,
                      group_size=64, exec_mode="dense"),
        mlp_kind="swiglu", norm="rmsnorm", pos="rope",
        scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="granite-moe-1b-a400m", family="moe", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
