"""hubert-xlarge [arXiv:2106.07447] — encoder-only audio transformer.

48L d_model=1280 16H (MHA kv=16) d_ff=5120, 504 cluster-classification
targets. The wav2vec2-style conv feature extractor is a STUB per the
assignment: ``input_specs`` provides precomputed 512-d frame embeddings;
the model projects 512 -> 1280 and runs the BERT-like encoder.

Encoder-only: no autoregressive step, so decode_32k / long_500k are skipped
(documented); prefill_32k is a 32768-frame encoder forward pass.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_DECODE_ENC, SKIP_LONG_ENC, register
from repro.models.transformer import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504, d_head=80,
        causal=False,
        mlp_kind="gelu", norm="layernorm", norm_position="pre",
        pos="learned", max_seq_len=65536,
        input_kind="embeds", frontend_dim=512,
        tie_embeddings=False,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=32, d_head=16,
        causal=False,
        mlp_kind="gelu", norm="layernorm", pos="learned", max_seq_len=256,
        input_kind="embeds", frontend_dim=24,
        tie_embeddings=False, scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="hubert-xlarge", family="audio", full=full, smoke=smoke,
    skip_shapes=(SKIP_DECODE_ENC, SKIP_LONG_ENC),
    source="arXiv:2106.07447",
))
