"""The paper's own models: BERT-base, BERT-6L, OPT-125m, ViT-S/16-style.

These drive the paper-table benchmarks; the reduced ``*_tiny`` variants run
the same protocol at CPU scale (same family: post-LN MLM encoder for BERT,
pre-LN CLM decoder for OPT, encoder-with-patch-embeds for ViT).
"""
import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def bert_base() -> ModelConfig:
    return ModelConfig(
        name="bert-base", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=30522, d_head=64,
        causal=False, norm="layernorm", norm_position="post",
        mlp_kind="gelu", pos="learned", max_seq_len=512,
        tie_embeddings=True, scan_layers=False, remat=False,
    )


def bert_6l(seq_len: int = 128) -> ModelConfig:
    return ModelConfig(
        name="bert-6l", n_layers=6, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=30522, d_head=64,
        causal=False, norm="layernorm", norm_position="post",
        mlp_kind="gelu", pos="learned", max_seq_len=max(seq_len, 512),
        tie_embeddings=True, scan_layers=False, remat=False,
    )


def bert_tiny(vocab: int = 2048, seq_len: int = 128) -> ModelConfig:
    """Reduced BERT family for CPU-scale paper-protocol benchmarks."""
    return ModelConfig(
        name="bert-tiny", n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=vocab, d_head=32,
        causal=False, norm="layernorm", norm_position="post",
        mlp_kind="gelu", pos="learned", max_seq_len=max(seq_len, 128),
        tie_embeddings=True, scan_layers=False, remat=False,
    )


def opt_125m() -> ModelConfig:
    return ModelConfig(
        name="opt-125m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=50272, d_head=64,
        causal=True, norm="layernorm", norm_position="pre",
        mlp_kind="relu", pos="learned", max_seq_len=2048,
        tie_embeddings=True, scan_layers=False, remat=False,
        init_std=0.006,
    )


def opt_tiny(vocab: int = 2048, seq_len: int = 256) -> ModelConfig:
    return ModelConfig(
        name="opt-tiny", n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=vocab, d_head=32,
        causal=True, norm="layernorm", norm_position="pre",
        mlp_kind="relu", pos="learned", max_seq_len=max(seq_len, 256),
        tie_embeddings=True, scan_layers=False, remat=False,
        init_std=0.006,
    )


def vit_s16() -> ModelConfig:
    """ViT-S/16 as an encoder over 197 patch embeddings (frontend stubbed;
    classification head = 1000-way 'vocab')."""
    return ModelConfig(
        name="vit-s16", n_layers=12, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab_size=1000, d_head=64,
        causal=False, norm="layernorm", norm_position="pre",
        mlp_kind="gelu", pos="learned", max_seq_len=256,
        input_kind="embeds", frontend_dim=384,
        tie_embeddings=False, scan_layers=False, remat=False,
    )
