"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064. phi3-mini text
backbone + CLIP vision frontend. Per assignment the modality frontend is a
STUB: ``input_specs`` supplies precomputed patch embeddings (576 = 24x24
CLIP-style patches at d_model) as a prefix to the token sequence.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064, d_head=96,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope", rope_theta=10000.0,
        input_kind="mixed", n_prefix_embeds=576,
        tie_embeddings=False,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3v-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128, d_head=16,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope",
        input_kind="mixed", n_prefix_embeds=8,
        tie_embeddings=False, scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="phi-3-vision-4.2b", family="vlm", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
))
