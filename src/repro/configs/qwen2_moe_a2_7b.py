"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) vocab=151936; MoE: 60 routed experts top-4
(per-expert d_ff=1408) + 4 shared experts (shared intermediate 5632).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig
from repro.nn.moe import MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=151936, d_head=128,
        moe=MoEConfig(n_experts=60, top_k=4, d_ff=1408,
                      n_shared_experts=4, shared_d_ff=5632,
                      capacity_factor=1.25, group_size=4096),
        mlp_kind="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
        tie_embeddings=False,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab_size=128, d_head=16,
        moe=MoEConfig(n_experts=6, top_k=2, d_ff=32, n_shared_experts=2,
                      shared_d_ff=48, capacity_factor=2.0, group_size=64,
                      exec_mode="dense"),
        mlp_kind="swiglu", norm="rmsnorm", pos="rope",
        tie_embeddings=False, scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="qwen2-moe-a2.7b", family="moe", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
