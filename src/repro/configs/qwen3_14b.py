"""qwen3-14b [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk-norm.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, SKIP_LONG, register
from repro.models.transformer import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab_size=151936, d_head=128,
        qk_norm=True,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
        tie_embeddings=False,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab_size=128, d_head=8, qk_norm=True,
        mlp_kind="swiglu", norm="rmsnorm", pos="rope",
        tie_embeddings=False, scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="qwen3-14b", family="dense", full=full, smoke=smoke,
    skip_shapes=(SKIP_LONG,),
    source="hf:Qwen/Qwen3-8B",
))
