"""recurrentgemma-9b [arXiv:2402.19427] — Griffin hybrid.

38L d_model=4096, 16H local attention (MQA kv=1, window 2048), RG-LRU
recurrent blocks at 2:1 ratio: pattern (griffin, griffin, local_attn) x 12
groups + 2 trailing griffin blocks = 38 layers. d_ff=12288, vocab=256000.
Sub-quadratic -> runs the long_500k cell.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig
from repro.nn.recurrent import RGLRUConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000, d_head=256,
        pattern=("griffin", "griffin", "local_attn"), window=2048,
        rglru=RGLRUConfig(width=4096, conv_width=4),
        embed_scale=True,
        mlp_kind="geglu", norm="rmsnorm", pos="rope", rope_theta=10000.0,
        tie_embeddings=True,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=128, d_head=16,
        pattern=("griffin", "griffin", "local_attn"), window=8,
        rglru=RGLRUConfig(width=64, conv_width=4),
        embed_scale=True,
        mlp_kind="geglu", norm="rmsnorm", pos="rope",
        scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="recurrentgemma-9b", family="hybrid", full=full, smoke=smoke,
    skip_shapes=(),              # sub-quadratic: runs long_500k
    source="arXiv:2402.19427",
))
