"""xlstm-1.3b [arXiv:2405.04517].

48 blocks, d_model=2048, 4 heads, d_ff=0 (the m/sLSTM blocks carry their
own projections), vocab=50304 (gpt-neox tokenizer). Block ratio 7:1
mLSTM:sLSTM. Recurrent -> runs the long_500k cell.

The paper's clipped softmax / gated attention do NOT apply (no token-axis
softmax); the cells' output gates already provide the explicit no-op path.
See DESIGN.md §Arch-applicability.
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig
from repro.nn.xlstm import XLSTMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, d_head=512,
        pattern=("mlstm",) * 7 + ("slstm",),
        xlstm=XLSTMConfig(d_model=2048, n_heads=4, chunk_size=128),
        mlp_kind="none", norm="layernorm", pos="none",
        tie_embeddings=True,
        vocab_pad_to=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        n_layers=4, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=128, d_head=8,
        pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        xlstm=XLSTMConfig(d_model=32, n_heads=4, chunk_size=8),
        mlp_kind="none", norm="layernorm", pos="none",
        scan_layers=False, remat=False,
    )


register(ArchSpec(
    arch_id="xlstm-1.3b", family="ssm", full=full, smoke=smoke,
    skip_shapes=(),              # recurrent: runs long_500k
    source="arXiv:2405.04517",
))
