"""Paper core: clipped softmax, gated attention, outlier telemetry."""
from repro.core.softmax import (
    ClippedSoftmaxConfig,
    clipped_softmax,
    clipped_softmax_from_config,
    softcap,
    softmax,
    stretch_and_clip,
)
from repro.core.gating import GateConfig, gate_logits, gate_param_count, gate_probs, init_gate
from repro.core.attention import (
    AttentionConfig,
    attention,
    chunked_attention,
    dense_attention,
    make_attention_mask,
    paged_attention,
)
from repro.core.outliers import (
    OutlierStats,
    collect_activation_stats,
    infinity_norm,
    kurtosis,
    outlier_counts_by_dim,
    outlier_counts_by_token,
    outlier_mask,
)

__all__ = [
    "ClippedSoftmaxConfig", "clipped_softmax", "clipped_softmax_from_config",
    "softcap", "softmax", "stretch_and_clip",
    "GateConfig", "gate_logits", "gate_param_count", "gate_probs", "init_gate",
    "AttentionConfig", "attention", "chunked_attention", "dense_attention",
    "make_attention_mask", "paged_attention",
    "OutlierStats", "collect_activation_stats", "infinity_norm", "kurtosis",
    "outlier_counts_by_dim", "outlier_counts_by_token", "outlier_mask",
]
