"""Multi-head attention with the paper's modifications, GQA, local windows,
logit soft-capping and qk-norm — the core op the whole model zoo shares.

Three execution paths:

  * ``dense_attention``   — materializes the (Tq, Tk) probability matrix.
    Reference semantics; used for short sequences, decode steps and as the
    oracle for the Pallas kernels.
  * ``chunked_attention`` — flash-attention-style blockwise streaming over
    KV; O(T) memory. For the *clipped* softmax the affine stretch+clip is a
    function of globally-normalized probabilities, so we run the classic
    2-pass scheme: pass 1 accumulates the online (m, Z); pass 2 applies
    stretch_and_clip per block and accumulates P·V. Vanilla softmax takes
    the 1-pass online path. This is the XLA (non-Pallas) implementation the
    dry-run lowers; `repro.kernels.flash_attention` is the TPU Pallas twin.
  * ``paged_attention``   — serving decode over a paged KV cache: K/V live
    in a global block pool ``(num_blocks, block_size, Hkv, Dh)`` and each
    batch row owns a *block table* of physical block ids. A dispatcher over
    two backends: the fused Pallas TPU kernel
    (``repro.kernels.paged_attention``) that reads pool blocks in place
    through a scalar-prefetched block table (default on TPU), and
    ``paged_attention_gather`` — the XLA oracle that gathers the row's
    virtual KV sequence block-by-block, then masks per block: unallocated
    table entries (id < 0) contribute nothing, and the usual causal/window
    mask over *logical* positions hides any garbage in the partially-filled
    tail block. See ``docs/serving.md``.

Layout convention: q (B, Tq, Hq, Dh); k/v (B, Tk, Hkv, Dh) with
Hq = G * Hkv (grouped-query attention).

The ``q_offset`` vector contract (introduced with the per-slot-position
decode engine, PR 1): everywhere a query block is positioned inside the full
sequence — ``make_attention_mask``, the chunked masks, ``dense_attention``
and ``paged_attention`` — the offset may be either a shared python/scalar
position or a per-row ``(B,)`` int32 vector. With a vector, masks acquire a
leading batch dimension ``(B, Tq, Tk)`` and every row attends at its own
absolute position; this is what lets the continuous batcher decode a batch
whose rows sit at unrelated sequence positions in ONE fused step.

Both paged backends already accept Tq > 1 query blocks per row, which is
the read half of speculative decoding: a verifying tick reads k+1 query
positions against the row's whole cached prefix in one paged read. The
causal mask over LOGICAL positions is what makes that sound — any
stale entry a rejected draft left at position p is invisible to every
query with q_pos < p, and by the time a query reaches p the entry has
been rewritten (bit-identically) by the token actually banked there.
See ``serving.decode.make_spec_step`` for the full argument.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.softmax import (
    ClippedSoftmaxConfig,
    softcap,
    softmax,
    stretch_and_clip,
)

Array = jax.Array

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    d_head: int
    causal: bool = True
    window: Optional[int] = None            # local attention window (tokens back)
    logit_softcap: Optional[float] = None   # gemma-2 style tanh cap
    softmax: ClippedSoftmaxConfig = ClippedSoftmaxConfig()
    chunk_size: int = 512                   # KV block for the chunked path

    @property
    def group_size(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads


def make_attention_mask(
    q_len: int,
    kv_len: int,
    causal: bool,
    window: Optional[int] = None,
    q_offset=0,
    dtype=jnp.bool_,
) -> Array:
    """Boolean attention mask, True = may attend.

    ``q_offset`` positions the query block inside the full sequence — used
    both by chunked attention and by decode (q_offset = cache position). It
    may be a scalar (shared position, returns (q_len, kv_len)) or a per-row
    (B,) vector (slot-pool decode, returns (B, q_len, kv_len)).
    """
    off = jnp.asarray(q_offset, jnp.int32)
    q_pos = (off[..., None] + jnp.arange(q_len))[..., :, None]   # (..., Tq, 1)
    k_pos = jnp.arange(kv_len)                                   # (Tk,)
    mask = jnp.ones(q_pos.shape[:-1] + (kv_len,), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask.astype(dtype)


def _expand_kv(k: Array, group: int) -> Array:
    """(B, S, Hkv, D) -> (B, S, Hkv, G, D) broadcast view for GQA einsums."""
    return jnp.broadcast_to(
        k[:, :, :, None, :], (*k.shape[:3], group, k.shape[-1])
    )


def attention_logits(q: Array, k: Array, cfg: AttentionConfig) -> Array:
    """(B, Tq, Hkv, G, Tk) scaled and (optionally) soft-capped logits."""
    b, tq, hq, d = q.shape
    g = cfg.group_size
    qg = q.reshape(b, tq, cfg.n_kv_heads, g, d)
    scale = d ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", (qg * scale).astype(jnp.float32), k.astype(jnp.float32))
    return softcap(logits, cfg.logit_softcap)


def dense_attention(
    q: Array,
    k: Array,
    v: Array,
    cfg: AttentionConfig,
    mask: Optional[Array] = None,
    q_offset=0,
    gate_pi: Optional[Array] = None,
) -> Array:
    """Reference attention. Returns (B, Tq, Hq, Dh).

    ``mask``: optional (Tq, Tk) shared or (B, Tq, Tk) per-row boolean.
    ``q_offset``: scalar or per-row (B,) query offset (slot-pool decode).
    ``gate_pi``: optional (B, Tq, Hq) gating probabilities (paper Eq. 5).
    """
    b, tq, hq, d = q.shape
    tk = k.shape[1]
    logits = attention_logits(q, k, cfg)               # (B, Hkv, G, Tq, Tk)
    if mask is None:
        mask = make_attention_mask(tq, tk, cfg.causal, cfg.window, q_offset)
    if mask.ndim == 3:                                 # per-row (B, Tq, Tk)
        mask = mask[:, None, None]
    mask_b = jnp.broadcast_to(mask.astype(jnp.bool_), logits.shape) if mask.ndim < 5 else mask

    sm = cfg.softmax
    if sm.is_vanilla:
        probs = softmax(logits, axis=-1, where=mask_b)
    else:
        gamma = sm.resolve_gamma(tk)
        probs = softmax(logits, axis=-1, where=mask_b)
        probs = stretch_and_clip(probs, gamma, sm.zeta)
        # clipped probabilities of masked entries are clip(gamma,0,1)=0 since
        # softmax emitted 0 there and gamma <= 0; nothing extra needed.
    probs = probs.astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    out = out.reshape(b, tq, hq, d)
    if gate_pi is not None:
        out = out * gate_pi[..., None].astype(out.dtype)
    return out


def _chunk_mask(idx, c, tk, tq, q_offset, cfg: AttentionConfig) -> Array:
    """Validity mask of one KV chunk: (Tq, c) for a scalar ``q_offset``,
    (B, Tq, c) for a per-row vector offset."""
    off = jnp.asarray(q_offset, jnp.int32)
    q_pos = (off[..., None] + jnp.arange(tq))[..., :, None]      # (..., Tq, 1)
    k_pos = idx * c + jnp.arange(c)
    mask = jnp.broadcast_to(k_pos < tk, q_pos.shape[:-1] + (c,))  # padding
    if cfg.causal:
        mask &= k_pos <= q_pos
    if cfg.window is not None:
        mask &= k_pos > q_pos - cfg.window
    return mask


def _lift_mask(mask: Array) -> Array:
    """Lift a (Tq, c) / (B, Tq, c) mask against (B, Hkv, G, Tq, c) logits."""
    return mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]


def _online_pass(q, k, v, cfg: AttentionConfig, q_offset) -> Tuple[Array, Array, Array]:
    """1-pass online softmax over KV chunks. Returns (acc, m, z) where
    acc = sum exp(s - m) v, per query. Shapes:
      acc (B, Hkv, G, Tq, D); m, z (B, Hkv, G, Tq)."""
    b, tq, hq, d = q.shape
    g = cfg.group_size
    hkv = cfg.n_kv_heads
    c = cfg.chunk_size
    tk = k.shape[1]
    n_chunks = (tk + c - 1) // c
    pad = n_chunks * c - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, c, hkv, d)
    vc = v.reshape(b, n_chunks, c, hkv, d)
    qg = (q * d ** -0.5).reshape(b, tq, hkv, g, d).astype(jnp.float32)

    def body(carry, blk):
        acc, m, z = carry
        kb, vb, idx = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(jnp.float32))
        s = softcap(s, cfg.logit_softcap)
        mask = _chunk_mask(idx, c, tk, tq, q_offset, cfg)
        s = jnp.where(_lift_mask(mask), s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        z_new = z * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (acc_new, m_new, z_new), None

    acc0 = jnp.zeros((b, hkv, g, tq, d), jnp.float32)
    m0 = jnp.full((b, hkv, g, tq), NEG_INF, jnp.float32)
    z0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (acc, m, z), _ = jax.lax.scan(
        body, (acc0, m0, z0), (kc_t, vc_t, jnp.arange(n_chunks))
    )
    return acc, m, z


def _clipped_second_pass(q, k, v, m, z, cfg: AttentionConfig, q_offset) -> Array:
    """Pass 2 for clipped softmax: accumulate clip((z-g)·p + g)·V blockwise."""
    b, tq, hq, d = q.shape
    g = cfg.group_size
    hkv = cfg.n_kv_heads
    c = cfg.chunk_size
    tk = k.shape[1]
    gamma = cfg.softmax.resolve_gamma(tk)
    zeta = cfg.softmax.zeta
    n_chunks = (tk + c - 1) // c
    pad = n_chunks * c - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(b, n_chunks, c, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, c, hkv, d), 1, 0)
    qg = (q * d ** -0.5).reshape(b, tq, hkv, g, d).astype(jnp.float32)
    z_safe = jnp.maximum(z, jnp.finfo(jnp.float32).tiny)

    def body(acc, blk):
        kb, vb, idx = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(jnp.float32))
        s = softcap(s, cfg.logit_softcap)
        mask = _chunk_mask(idx, c, tk, tq, q_offset, cfg)
        p = jnp.exp(s - m[..., None]) / z_safe[..., None]
        p = stretch_and_clip(p, gamma, zeta)
        p = jnp.where(_lift_mask(mask), p, 0.0)
        return acc + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)), None

    acc0 = jnp.zeros((b, hkv, g, tq, d), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (kc, vc, jnp.arange(n_chunks)))
    return acc


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    cfg: AttentionConfig,
    q_offset=0,
    gate_pi: Optional[Array] = None,
) -> Array:
    """Flash-style O(T)-memory attention with vanilla OR clipped softmax."""
    b, tq, hq, d = q.shape
    acc, m, z = _online_pass(q, k, v, cfg, q_offset)
    if cfg.softmax.is_vanilla:
        out = acc / jnp.maximum(z, jnp.finfo(jnp.float32).tiny)[..., None]
    else:
        out = _clipped_second_pass(q, k, v, m, z, cfg, q_offset)
    out = jnp.moveaxis(out, 3, 1).reshape(b, tq, hq, d).astype(v.dtype)
    if gate_pi is not None:
        out = out * gate_pi[..., None].astype(out.dtype)
    return out


def paged_attention_gather(
    q: Array,
    k_pool: Array,
    v_pool: Array,
    block_table: Array,
    cfg: AttentionConfig,
    q_offset=0,
    gate_pi: Optional[Array] = None,
    live_widths: Optional[Array] = None,
    k_scale: Optional[Array] = None,
    v_scale: Optional[Array] = None,
) -> Array:
    """Gather-based attention over a paged KV cache. Returns (B, Tq, Hq, Dh).

    The XLA reference/oracle path: each row's blocks are gathered and
    flattened into a (B, W*block_size, Hkv, Dh) virtual KV sequence indexed
    by *logical* position, so the standard causal/window mask built from
    ``q_offset`` (scalar or per-row (B,) vector) applies unchanged; a
    per-block validity mask additionally hides unallocated entries (id < 0).
    Masked positions contribute exact zeros to the softmax, so the result is
    bitwise identical to dense attention over a contiguous cache of the same
    length W*block_size holding the same tokens. If ``cfg.softmax`` uses
    ``alpha``, gamma resolves from the gathered axis length W*block_size —
    callers slicing the table to a live prefix must pre-resolve gamma from
    the LOGICAL length (``paged_attention`` does).

    ``live_widths`` ((B,) int32, optional): each row's OWN count of live
    block-table entries. Entries at or beyond a row's count are treated as
    unallocated — their pool gather is redirected to block 0 and the
    gathered lanes are zeroed, so the per-row read is confined to the
    row's live prefix instead of the batch max. Allocation is prefix-dense,
    so those entries are ``-1`` in real schedules and masking them is
    bitwise-neutral; the mask makes the row's valid work (and, with a
    sliced table, its gather) track the row rather than the widest row in
    the tick.

    ``k_scale``/``v_scale`` ((num_blocks, block_size) f32, optional): the
    int8 pool's per-slot scale vectors. Dequantization is fused into the
    same block gather — scales are gathered with the identical ``safe``
    indices and multiplied back before the softmax, so the virtual KV
    sequence the mask sees is already fp. Stale scales in recycled blocks
    are hidden by the same validity/causal masks as stale KV."""
    b, w = block_table.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    tq, tk = q.shape[1], w * bs
    valid_entry = block_table >= 0                               # (B, W)
    if live_widths is not None:
        valid_entry &= jnp.arange(w)[None, :] < \
            jnp.asarray(live_widths, jnp.int32)[:, None]
    safe = jnp.where(valid_entry, jnp.clip(block_table, 0, nb - 1), 0)
    k = k_pool[safe].reshape(b, tk, *k_pool.shape[2:])
    v = v_pool[safe].reshape(b, tk, *v_pool.shape[2:])
    if k_scale is not None:
        ks = k_scale[safe].reshape(b, tk)
        k = k.astype(jnp.float32) * ks[:, :, None, None]
    if v_scale is not None:
        vs = v_scale[safe].reshape(b, tk)
        v = v.astype(jnp.float32) * vs[:, :, None, None]
    valid = jnp.repeat(valid_entry, bs, axis=1)                  # (B, Tk)
    if live_widths is not None:
        # dead lanes are already masked out of the softmax below; zeroing
        # the gathered values too keeps every dead-lane flop an exact zero
        zmask = valid[:, :, None, None]
        k = jnp.where(zmask, k, jnp.zeros((), k.dtype))
        v = jnp.where(zmask, v, jnp.zeros((), v.dtype))
    mask = make_attention_mask(tq, tk, cfg.causal, cfg.window, q_offset)
    mask = jnp.broadcast_to(mask, (b, tq, tk)) & valid[:, None, :]
    return dense_attention(q, k, v, cfg, mask=mask, gate_pi=gate_pi)


def paged_attention(
    q: Array,
    k_pool: Array,
    v_pool: Array,
    block_table: Array,
    cfg: AttentionConfig,
    q_offset=0,
    gate_pi: Optional[Array] = None,
    *,
    live_width: Optional[int] = None,
    live_widths: Optional[Array] = None,
    k_scale: Optional[Array] = None,
    v_scale: Optional[Array] = None,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> Array:
    """Paged-KV attention dispatcher. Returns (B, Tq, Hq, Dh).

    ``k_pool``/``v_pool``: (num_blocks, block_size, Hkv, Dh) global pools
    shared by every batch row. ``block_table``: (B, W) int32 physical block
    ids; entry j maps the row's logical token range
    [j*block_size, (j+1)*block_size) onto pool block ``block_table[b, j]``,
    with -1 marking an unallocated entry.

    Two backends:

      * ``"kernel"`` — the fused Pallas TPU kernel
        (``repro.kernels.paged_attention``): pool blocks are read in place
        through a scalar-prefetched block table; no gather, no materialized
        virtual sequence. Default on TPU.
      * ``"gather"`` — ``paged_attention_gather``, the XLA path that
        materializes each row's virtual KV sequence. Bitwise-equal to dense
        attention; the oracle the kernel is swept against, the fallback off
        TPU (where the kernel would run in slow interpret mode), and the
        path ``backend="auto"`` picks on CPU/GPU.

    ``live_width``: optional static number of block-table entries actually
    in use (allocation is prefix-dense — the scheduler fills tables from
    entry 0). When given, only ``table[:, :live_width]`` is visited by
    EITHER backend, making the per-tick cost proportional to live tokens
    instead of the table width W. The clipped softmax's ``alpha`` is
    resolved against the LOGICAL length W*block_size *before* slicing, so
    the clip threshold gamma = -alpha/max_len is invariant to how many
    blocks are live (and to ``live_width`` itself) — positions beyond the
    live prefix are causally unreachable, so slicing is exact, not an
    approximation.

    ``live_widths``: optional (B,) int32 vector of each row's OWN live
    entry count, masking the gather path's per-row read at the row rather
    than the tick max (see ``paged_attention_gather``; the shapes stay
    static — ``live_width`` bounds them, ``live_widths`` confines the valid
    work inside them). The kernel backend ignores it: its per-block masks
    already skip unallocated entries, and a per-row ``pl.when`` early exit
    is on-TPU tuning work (ROADMAP).

    ``k_scale``/``v_scale``: per-slot scale vectors (num_blocks,
    block_size) of an int8 pool (``init_paged_cache(kv_int8=True)``).
    Both backends fuse dequantization into their block reads: the gather
    path gathers scales alongside blocks, the kernel DMAs each block's
    scale vector through the same table-driven index_map and multiplies in
    the epilogue of the block load. Scale arrays are pool-indexed, not
    table-indexed, so ``live_width`` slicing leaves them untouched.
    """
    b, w_full = block_table.shape
    bs = k_pool.shape[1]
    logical_len = w_full * bs
    sm = cfg.softmax
    if not sm.is_vanilla:
        # pin gamma to the logical max_len: dense_attention and the kernel
        # would otherwise resolve it from the (possibly sliced) KV axis
        gamma, zeta = sm.resolve_gamma(logical_len), sm.zeta
        cfg = dataclasses.replace(
            cfg, softmax=ClippedSoftmaxConfig(gamma=gamma, zeta=zeta))
    else:
        gamma, zeta = 0.0, 1.0
    if live_width is not None:
        block_table = block_table[:, :max(1, min(int(live_width), w_full))]
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "gather"
    if backend == "kernel":
        from repro.kernels.paged_attention import paged_mha
        return paged_mha(q, k_pool, v_pool, block_table, q_offset, gate_pi,
                         causal=cfg.causal, window=cfg.window,
                         softcap=cfg.logit_softcap, gamma=gamma, zeta=zeta,
                         k_scale=k_scale, v_scale=v_scale,
                         interpret=interpret)
    if backend != "gather":
        raise ValueError(f"unknown paged-attention backend {backend!r}")
    return paged_attention_gather(q, k_pool, v_pool, block_table, cfg,
                                  q_offset=q_offset, gate_pi=gate_pi,
                                  live_widths=live_widths,
                                  k_scale=k_scale, v_scale=v_scale)


def attention(
    q: Array,
    k: Array,
    v: Array,
    cfg: AttentionConfig,
    q_offset=0,
    gate_pi: Optional[Array] = None,
    force_dense: bool = False,
) -> Array:
    """Dispatcher: dense for small problems / decode, chunked for long T.

    Routing (pinned by tests/test_attention.py::test_dispatcher_routing):
    dense when forced, when decoding (tq == 1) with tk <= 8192, or when
    tq > 1 and tq*tk <= 2048^2; chunked otherwise (long-T prefill/training
    and long-context decode). The seed's condition chained these with an
    unparenthesized ``... or tq == 1 and tk <= 8192`` — the precedence trap
    this explicit form replaces — and ``force_dense`` did not actually
    force for large tq*tk.
    """
    tq, tk = q.shape[1], k.shape[1]
    if force_dense or (tq == 1 and tk <= 8192) or (tq > 1 and tq * tk <= 2048 * 2048):
        return dense_attention(q, k, v, cfg, q_offset=q_offset, gate_pi=gate_pi)
    return chunked_attention(q, k, v, cfg, q_offset=q_offset, gate_pi=gate_pi)
