"""Gating modules for gated attention (paper Section 4.2, Appendix B.1).

Gated_attention(x) = sigmoid(G(x)) ⊙ softmax(QK^T/sqrt(d)) V        (Eq. 5)

G is defined per head: G_i : R^{d_head} -> R, shared across token positions,
NOT shared across heads. Three parameterizations from Table 4:

  - "linear":           n_heads × Linear(d_head -> 1)
  - "mlp":              n_heads × MLP(d_head -> n_hid -> 1), ReLU
  - "all_heads_linear": Linear(d_model -> n_heads)  (mixes heads)

The bias is initialized to ``b_init`` so the initial gate probability is
pi_init = sigmoid(b_init) (paper Sec. 5.3; reasonable pi_init ~ [0.25, 0.9]
for BERT, [0.1, 0.5] for ViT).

For the fine-tuning recipe (paper App. B.6) ``output_scale=2.0`` with
b_init=0 makes the expected gate output 1 at init, approximating vanilla
attention on an already-trained network.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GateConfig:
    kind: str = "linear"          # "linear" | "mlp" | "all_heads_linear" | "none"
    n_hid: int = 4                # hidden width for the "mlp" kind
    b_init: float = 0.0           # gate bias init; pi_init = sigmoid(b_init)
    output_scale: float = 1.0     # 2.0 for the fine-tuning recipe (App. B.6)

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    @staticmethod
    def from_pi_init(pi_init: float, kind: str = "linear", **kw) -> "GateConfig":
        pi = min(max(pi_init, 1e-6), 1.0 - 1e-6)
        return GateConfig(kind=kind, b_init=math.log(pi / (1.0 - pi)), **kw)


def _he_normal(key: Array, shape, fan_in: int, dtype) -> Array:
    std = math.sqrt(2.0 / max(fan_in, 1))
    return (std * jax.random.normal(key, shape)).astype(dtype)


def init_gate(
    key: Array,
    cfg: GateConfig,
    n_heads: int,
    d_head: int,
    d_model: int,
    dtype: jnp.dtype = jnp.float32,
) -> Params:
    """Parameter pytree for the gating module. Empty dict if disabled."""
    if not cfg.enabled:
        return {}
    b = jnp.full((n_heads,), cfg.b_init, dtype=dtype)
    if cfg.kind == "linear":
        w = _he_normal(key, (n_heads, d_head), d_head, dtype)
        return {"w": w, "b": b}
    if cfg.kind == "mlp":
        k1, k2 = jax.random.split(key)
        return {
            "w1": _he_normal(k1, (n_heads, d_head, cfg.n_hid), d_head, dtype),
            "b1": jnp.zeros((n_heads, cfg.n_hid), dtype=dtype),
            "w2": _he_normal(k2, (n_heads, cfg.n_hid), cfg.n_hid, dtype),
            "b2": b,
        }
    if cfg.kind == "all_heads_linear":
        w = _he_normal(key, (d_model, n_heads), d_model, dtype)
        return {"w": w, "b": b}
    raise ValueError(f"unknown gate kind: {cfg.kind!r}")


def gate_logits(params: Params, cfg: GateConfig, x_heads: Array, x_model: Array) -> Array:
    """Raw gate logits G(x), shape (..., T, n_heads).

    ``x_heads``: (..., T, n_heads, d_head) — the per-head view of the input.
    ``x_model``: (..., T, d_model)        — the flat view (for all_heads_linear).
    """
    if cfg.kind == "linear":
        return jnp.einsum("...thd,hd->...th", x_heads, params["w"]) + params["b"]
    if cfg.kind == "mlp":
        h = jnp.einsum("...thd,hdn->...thn", x_heads, params["w1"]) + params["b1"]
        h = jax.nn.relu(h)
        return jnp.einsum("...thn,hn->...th", h, params["w2"]) + params["b2"]
    if cfg.kind == "all_heads_linear":
        return jnp.einsum("...td,dh->...th", x_model, params["w"]) + params["b"]
    raise ValueError(f"unknown gate kind: {cfg.kind!r}")


def gate_probs(params: Params, cfg: GateConfig, x_heads: Array, x_model: Array) -> Array:
    """pi = output_scale * sigmoid(G(x)), shape (..., T, n_heads)."""
    pi = jax.nn.sigmoid(gate_logits(params, cfg, x_heads, x_model))
    if cfg.output_scale != 1.0:
        pi = cfg.output_scale * pi
    return pi


def gate_param_count(cfg: GateConfig, n_heads: int, d_head: int, d_model: int) -> int:
    """Memory overhead accounting (paper Table 4)."""
    if not cfg.enabled:
        return 0
    if cfg.kind == "linear":
        return n_heads * (d_head + 1)
    if cfg.kind == "mlp":
        return n_heads * (cfg.n_hid * (d_head + 2) + 1)
    if cfg.kind == "all_heads_linear":
        return n_heads * (d_model + 1)
    raise ValueError(cfg.kind)
