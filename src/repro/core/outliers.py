"""Outlier telemetry (paper Section 3 / Section 5 metrics).

Metrics the paper uses to quantify outliers, all computed on the *output of
an attention layer* (or any activation tensor):

  - max infinity norm  ``max ||x||_inf``  averaged across a validation set,
  - kurtosis of x averaged across layers,
  - 6-sigma outlier counts per hidden dimension / token position (Fig. 1),

These correlate with quantizability (Bondarenko et al. 2021; Chmiel et al.
2020). The training loop logs them every eval to reproduce the paper's
outlier-growth curves.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def infinity_norm(x: Array) -> Array:
    """max |x| over everything except a leading batch axis is NOT taken:
    the paper's 'maximum infinity norm' is the max abs value of the tensor."""
    return jnp.max(jnp.abs(x))


def kurtosis(x: Array, axis=None, eps: float = 1e-12) -> Array:
    """Pearson kurtosis E[(x-mu)^4] / sigma^4 (not excess)."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    d = x - mu
    var = jnp.mean(d * d, axis=axis, keepdims=True)
    m4 = jnp.mean(d ** 4, axis=axis, keepdims=True)
    k = m4 / jnp.maximum(var * var, eps)
    return jnp.squeeze(k) if axis is None else jnp.squeeze(k, axis=axis)


def outlier_mask(x: Array, n_sigma: float = 6.0) -> Array:
    """Boolean mask of values exceeding n_sigma std-devs from the tensor mean
    (the paper follows Bondarenko et al. [4] with n_sigma = 6)."""
    mu = jnp.mean(x)
    sigma = jnp.std(x)
    return jnp.abs(x - mu) > n_sigma * sigma


def outlier_counts_by_dim(x: Array, n_sigma: float = 6.0) -> Array:
    """Histogram of outlier counts per hidden dimension (paper Fig. 1, green).

    x: (..., T, d_model) -> (d_model,) int32 counts.
    """
    mask = outlier_mask(x, n_sigma)
    return jnp.sum(mask.reshape(-1, x.shape[-1]), axis=0).astype(jnp.int32)


def outlier_counts_by_token(x: Array, n_sigma: float = 6.0) -> Array:
    """Histogram of outlier counts per token position (paper Fig. 1, blue).

    x: (B, T, d_model) -> (T,) int32 counts.
    """
    mask = outlier_mask(x, n_sigma)
    return jnp.sum(mask, axis=(0, 2)).astype(jnp.int32)


class OutlierStats:
    """Running aggregate across batches / layers, mirroring the paper's
    reporting: max inf-norm averaged across the validation set, kurtosis
    averaged across layers."""

    def __init__(self) -> None:
        self._inf_norms: List[float] = []      # one per batch (max over layers)
        self._kurtoses: List[float] = []       # one per (batch, layer)

    def update(self, layer_outputs: Sequence[Array]) -> None:
        per_layer_inf = [float(infinity_norm(y)) for y in layer_outputs]
        self._inf_norms.append(max(per_layer_inf))
        self._kurtoses.extend(float(kurtosis(y)) for y in layer_outputs)

    def summary(self) -> Dict[str, float]:
        if not self._inf_norms:
            return {"max_inf_norm": 0.0, "avg_kurtosis": 0.0}
        return {
            "max_inf_norm": sum(self._inf_norms) / len(self._inf_norms),
            "avg_kurtosis": sum(self._kurtoses) / max(len(self._kurtoses), 1),
        }


def collect_activation_stats(activations: Mapping[str, Array]) -> Dict[str, Dict[str, float]]:
    """One-shot metrics for a dict of named activations (telemetry hook)."""
    out: Dict[str, Dict[str, float]] = {}
    for name, act in activations.items():
        out[name] = {
            "inf_norm": float(infinity_norm(act)),
            "kurtosis": float(kurtosis(act)),
            "outliers_6sigma": int(jnp.sum(outlier_mask(act))),
        }
    return out
