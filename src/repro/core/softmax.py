"""Softmax variants from the paper (Section 4.1).

The paper's central numerical object: a softmax that can emit *exact zeros*
(and ones) with a finite input dynamic range, so attention heads that want a
no-op don't have to grow activation outliers.

    clipped_softmax(x; zeta, gamma) = clip((zeta - gamma) * softmax(x) + gamma, 0, 1)

with gamma <= 0 <= 1 <= zeta (Eq. 4). Only gamma < 0 (clipping at zero)
matters empirically (paper Table 1 / Table 8); zeta defaults to 1.

`ClippedSoftmaxConfig.resolve_gamma` implements the sequence-length-robust
parameterization gamma = -alpha / T from paper Section 5.2 (alpha in [2, 4]
works across T).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ClippedSoftmaxConfig:
    """Hyper-parameters of the clipped softmax (paper Eq. 4)."""

    gamma: float = 0.0          # lower stretch, <= 0; 0 disables low clipping
    zeta: float = 1.0           # upper stretch, >= 1; 1 disables high clipping
    # If set, gamma is derived per-call as -alpha / T (paper Sec. 5.2) and the
    # static `gamma` above is ignored.
    alpha: Optional[float] = None

    def resolve_gamma(self, seq_len: int) -> float:
        if self.alpha is not None:
            return -float(self.alpha) / float(seq_len)
        return float(self.gamma)

    @property
    def is_vanilla(self) -> bool:
        return self.alpha is None and self.gamma == 0.0 and self.zeta == 1.0


def softmax(logits: Array, axis: int = -1, where: Optional[Array] = None) -> Array:
    """Standard softmax with optional boolean mask (True = attend)."""
    if where is not None:
        logits = jnp.where(where, logits, jnp.finfo(logits.dtype).min)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=axis, keepdims=True))
    unnorm = jnp.exp(logits - m)
    if where is not None:
        unnorm = jnp.where(where, unnorm, 0.0)
    denom = jnp.sum(unnorm, axis=axis, keepdims=True)
    return unnorm / jnp.maximum(denom, jnp.finfo(logits.dtype).tiny)


def stretch_and_clip(probs: Array, gamma: float, zeta: float) -> Array:
    """Affine stretch (0,1)->(gamma,zeta) then clip back to [0,1] (Eq. 4).

    Split out so streaming/flash attention kernels can reuse the exact same
    epilogue on blockwise-normalized probabilities.
    """
    if gamma == 0.0 and zeta == 1.0:
        return probs
    y = (zeta - gamma) * probs + gamma
    return jnp.clip(y, 0.0, 1.0)


def clipped_softmax(
    logits: Array,
    gamma: float,
    zeta: float = 1.0,
    axis: int = -1,
    where: Optional[Array] = None,
) -> Array:
    """clip((zeta - gamma) * softmax(x) + gamma, 0, 1) — paper Eq. 4.

    Rows no longer sum to 1 in general; that is the point: probabilities of
    exactly 0 (and 1) are representable with finite logits, and clipped
    entries receive zero gradient so outliers stop being rewarded.
    """
    return stretch_and_clip(softmax(logits, axis=axis, where=where), gamma, zeta)


def clipped_softmax_from_config(
    logits: Array,
    cfg: ClippedSoftmaxConfig,
    axis: int = -1,
    where: Optional[Array] = None,
    seq_len: Optional[int] = None,
) -> Array:
    if cfg.is_vanilla:
        return softmax(logits, axis=axis, where=where)
    t = seq_len if seq_len is not None else logits.shape[axis]
    gamma = cfg.resolve_gamma(t)
    return clipped_softmax(logits, gamma=gamma, zeta=cfg.zeta, axis=axis, where=where)


def softcap(logits: Array, cap: Optional[float]) -> Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
