from repro.data.synthetic import SyntheticLM, SyntheticLMConfig

__all__ = ["SyntheticLM", "SyntheticLMConfig"]
