"""Deterministic synthetic language-modeling data.

A fixed (seeded) Zipf-weighted first-order Markov chain over the vocabulary
generates token streams with learnable structure — perplexity drops well
below uniform as a model trains, which is what the paper-protocol
benchmarks need (outlier growth appears when the model actually learns).

The pipeline is host-sharded and stateless-resumable: batch ``i`` is a pure
function of (seed, i), so fault-tolerant restarts just set the step counter
(no data-state checkpoint needed) and elastic re-runs stay deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    batch_size: int               # per-host batch
    seed: int = 0
    branching: int = 32           # out-degree of the Markov chain
    mask_prob: float = 0.15       # for MLM batches
    mask_token: int = 1
    n_special: int = 4            # reserved low token-ids


class SyntheticLM:
    def __init__(self, cfg: SyntheticLMConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, b = cfg.vocab_size, min(cfg.branching, cfg.vocab_size - cfg.n_special)
        # per-state successor sets + Zipf transition probabilities
        self._succ = rng.integers(cfg.n_special, v, size=(v, b), dtype=np.int64)
        p = 1.0 / np.arange(1, b + 1) ** 1.1
        self._p = p / p.sum()

    # -- core generator ----------------------------------------------------
    def _gen_tokens(self, rng: np.random.Generator, n_rows: int) -> np.ndarray:
        cfg = self.cfg
        toks = np.empty((n_rows, cfg.seq_len), dtype=np.int32)
        state = rng.integers(cfg.n_special, cfg.vocab_size, size=n_rows)
        choices = rng.choice(len(self._p), p=self._p,
                             size=(n_rows, cfg.seq_len))
        for t in range(cfg.seq_len):
            state = self._succ[state, choices[:, t]]
            toks[:, t] = state
        return toks

    def batch(self, index: int, kind: str = "clm") -> Dict[str, np.ndarray]:
        """Pure function of (seed, index). kinds: clm | mlm | frames."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        toks = self._gen_tokens(rng, cfg.batch_size)
        if kind == "clm":
            return {"tokens": toks, "labels": toks.copy()}
        if kind == "mlm":
            labels = np.full_like(toks, -100)
            mask = rng.random(toks.shape) < cfg.mask_prob
            labels[mask] = toks[mask]
            masked = toks.copy()
            # 80/10/10 masking like BERT
            r = rng.random(toks.shape)
            masked[mask & (r < 0.8)] = cfg.mask_token
            rand_tok = rng.integers(cfg.n_special, cfg.vocab_size, toks.shape)
            masked[mask & (r >= 0.9)] = rand_tok[mask & (r >= 0.9)]
            return {"tokens": masked, "labels": labels}
        if kind == "frames":
            # audio-style: continuous frame embeddings + cluster targets
            d = 24
            emb = rng.standard_normal((cfg.batch_size, cfg.seq_len, d)).astype(np.float32)
            return {"embeds": emb, "labels": toks % cfg.vocab_size}
        raise ValueError(kind)

    def iterate(self, kind: str = "clm", start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        i = start
        while True:
            yield self.batch(i, kind)
            i += 1
