from repro.distributed.sharding import (
    batch_specs,
    cache_specs_tree,
    maybe_constrain,
    param_rules,
    spec_for_path,
    tree_param_specs,
    tree_shardings,
)

__all__ = [
    "batch_specs", "cache_specs_tree", "maybe_constrain", "param_rules",
    "spec_for_path", "tree_param_specs", "tree_shardings",
]
