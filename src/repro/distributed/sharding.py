"""Rule-based parameter/activation sharding (DP / FSDP / TP / EP / SP).

Mesh axes:
  * ``pod``   — cross-pod data parallelism (gradient all-reduce over DCI)
  * ``data``  — intra-pod data parallel + FSDP (ZeRO-3-style weight shard)
  * ``model`` — tensor/expert parallel

Rules are (regex over '/'-joined param path) -> PartitionSpec of the
UNSTACKED tensor; scanned layer groups ("groups/...") automatically get a
leading ``None`` for the stacking axis. First match wins.

Profiles (select per run — and per §Perf hillclimb):
  * ``tp_fsdp``  — default training profile: weights sharded over
    (data, model); optimizer state follows params, so ZeRO-3 memory.
  * ``tp_only``  — weights sharded over model only, replicated over data —
    the serving profile (no per-step weight all-gather).
  * ``replicated`` — pure DP (small models).

Divisibility notes (why rules look like they do): every assigned arch has
d_model, d_ff, n_heads*d_head and d_head divisible by 16; vocab sizes,
expert counts (60) and kv-head counts (8, 1) are NOT uniformly divisible,
so those dims are never sharded as jit *arguments* (XLA rejects uneven arg
sharding); experts therefore shard internally over (data, model) on their
(d_model, d_ff) dims — "expert-TP". KV caches shard batch over data and
d_head (always /16) over model.
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.nn.module import flatten_params

Rules = List[Tuple[str, P]]


def _dp(mesh: Mesh) -> Any:
    """The composite data-parallel axis: ('pod','data') on multi-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def maybe_constrain(x, *logical: Optional[str]):
    """``with_sharding_constraint`` that resolves logical axes ('dp', 'tp')
    against whatever mesh is active at trace time, and silently no-ops when
    there is none (single-device tests). Layers use this to pin activation
    shardings (e.g. MoE dispatch group axes) without knowing mesh names."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return x
    if mesh is None or not mesh.axis_names:
        return x
    names = set(mesh.axis_names)

    def resolve(ax):
        if isinstance(ax, (tuple, list)):
            flat = []
            for a in ax:
                r = resolve(a)
                if isinstance(r, tuple):
                    flat.extend(r)
                elif r is not None:
                    flat.append(r)
            return tuple(flat) if flat else None
        if ax == "dp":
            got = tuple(a for a in ("pod", "data") if a in names)
            return got if got else None
        if ax == "tp":
            return "model" if "model" in names else None
        return ax if ax in names else None

    spec = P(*(resolve(a) for a in logical))
    return jax.lax.with_sharding_constraint(x, spec)


def param_rules(profile: str, mesh: Mesh) -> Rules:
    # "tp_seq" shares the tp_only weight layout; it differs only in
    # activation sharding (sequence/context parallelism, set by the caller)
    fs = "data" if profile == "tp_fsdp" else None     # FSDP axis (or not)
    mdl = "model" if profile != "replicated" else None
    if profile == "replicated":
        return [(r".*", P())]
    return [
        # embeddings: vocab (padded to 128-multiples) shards over model, so
        # the LM head contraction keeps logits vocab-sharded instead of
        # all-reducing a (B,T,vocab) f32 buffer
        (r".*pos_embed/table$", P(None, mdl)),
        (r".*(^|/)embed/table$", P(mdl, None)),
        (r".*frontend_proj/w$", P(None, mdl)),
        (r".*lm_head/w$", P(fs, mdl)),
        # attention projections
        (r".*/(q|k|v)/w$", P(fs, mdl)),
        (r".*/o/w$", P(mdl, fs)),
        (r".*/(qnorm|knorm)/scale$", P()),
        # attention gating module (paper) — tiny, replicate
        (r".*/gate/(w|b|w1|b1|w2|b2)$", P()),
        # dense MLP
        (r".*/mlp/(up|gate)/w$", P(fs, mdl)),
        (r".*/mlp/down/w$", P(mdl, fs)),
        # MoE: expert-TP (expert dim uneven across archs -> unsharded);
        # shared experts are plain MLPs
        (r".*/moe/router/w$", P()),
        (r".*/moe/w_(gate|up)$", P(None, fs, mdl)),
        (r".*/moe/w_down$", P(None, mdl, fs)),
        (r".*/moe/shared/(up|gate)/w$", P(fs, mdl)),
        (r".*/moe/shared/down/w$", P(mdl, fs)),
        # griffin / RG-LRU
        (r".*/griffin/(in_x|in_gate)/w$", P(fs, mdl)),
        (r".*/griffin/out/w$", P(mdl, fs)),
        (r".*/rglru/(w_a|w_x)/w$", P(fs, mdl)),
        (r".*/rglru/lambda$", P(mdl)),
        (r".*/griffin/conv/w$", P(None, mdl)),
        (r".*/griffin/conv/b$", P(mdl)),
        # xLSTM
        (r".*/blk/up/w$", P(fs, mdl)),
        (r".*/blk/(q|k|v)/w$", P(fs, mdl)),
        (r".*/blk/down/w$", P(mdl, fs)),
        (r".*/blk/ifgate/w$", P(mdl, None)),
        (r".*/blk/conv/w$", P(None, mdl)),
        (r".*/blk/conv/b$", P(mdl)),
        (r".*/blk/(zifo|ff_up|ff_gate)/w$", P(fs, mdl)),
        (r".*/blk/ff_down/w$", P(mdl, fs)),
        (r".*/blk/(rz|ri|rf|ro)$", P()),
        # biases / norm scales / everything small: replicate
        (r".*", P()),
    ]


def spec_for_path(path: str, rules: Rules, stacked: bool) -> P:
    for pat, spec in rules:
        if re.match(pat, path):
            if stacked:
                return P(None, *spec)
            return spec
    return P()


def tree_param_specs(tree: Any, profile: str, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``tree`` (params or a TrainState whose
    leaves' paths end with param paths)."""
    rules = param_rules(profile, mesh)
    leaves = list(flatten_params(tree))
    specs = []
    for path, leaf in leaves:
        stacked = "/groups/" in f"/{path}" or path.startswith("groups/")
        spec = spec_for_path(path, rules, stacked)
        # rank guard: never emit a spec longer than the tensor rank
        if len(spec) > leaf.ndim:
            spec = P(*tuple(spec)[: leaf.ndim])
        specs.append(spec)
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(tree: Any, profile: str, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_param_specs(tree, profile, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# Activation / input shardings
# --------------------------------------------------------------------------
def batch_specs(batch: Any, mesh: Mesh, shard_seq: bool = False,
                seq_axis: Optional[str] = None) -> Any:
    """tokens/labels (B,T): batch over (pod,data). ``shard_seq`` moves the
    sequence dim onto ``seq_axis`` ("data" for B=1 long-context decode,
    "model" for context-parallel prefill); batch stays on the dp axes when
    it still divides."""
    dp = _dp(mesh)
    n_dp = 1
    for ax in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[ax]

    def one(leaf):
        if leaf.ndim >= 2 and shard_seq:
            b_ax = dp if leaf.shape[0] % n_dp == 0 else None
            return P(b_ax, seq_axis or "data", *([None] * (leaf.ndim - 2)))
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(one, batch)


def cache_specs_tree(cache_tpl: Any, mesh: Mesh, cfg, batch: int) -> Any:
    """Decode-cache shardings. KV tensors (B, S, Hkv, Dh): batch over the
    data axes when divisible, sequence over 'data' otherwise (SP for the
    B=1 long-context cell); d_head always shards over 'model' (every arch's
    d_head is a multiple of 16). Recurrent states shard their feature dim
    over 'model'."""
    dp = _dp(mesh)
    n_dp = 1
    for ax in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[ax]

    shard_batch = batch % n_dp == 0
    b_ax = dp if shard_batch else None

    def one(path: str, leaf) -> P:
        stacked = path.startswith("groups/")
        shape = leaf.shape[1:] if stacked else leaf.shape
        ndim = len(shape)
        if path.endswith("pos_ids"):
            spec: Tuple = ()
        elif ndim == 4 and (path.endswith("/k") or path.endswith("/v")):
            # KV cache (B, S, Hkv, Dh): SP over seq when batch unshardable
            s_ax = None if shard_batch else "data"
            spec = (b_ax, s_ax, None, "model")
        elif ndim >= 2 and shape[0] == batch:
            # recurrent state (B, ..., feature)
            feat = shape[-1]
            f_ax = "model" if feat % mesh.shape["model"] == 0 else None
            spec = (b_ax,) + (None,) * (ndim - 2) + (f_ax,)
        elif ndim == 1 and shape[0] == batch:
            spec = (b_ax,)
        else:
            spec = ()
        if stacked:
            spec = (None,) + tuple(spec)
        return P(*spec)

    leaves = list(flatten_params(cache_tpl))
    specs = [one(path, leaf) for path, leaf in leaves]
    treedef = jax.tree_util.tree_structure(cache_tpl)
    return jax.tree_util.tree_unflatten(treedef, specs)
