"""Pallas TPU kernels (validated in interpret mode on CPU; see ref.py)."""
from repro.kernels.ops import (
    default_interpret,
    fake_quant_op,
    linear_w8a8,
    mha_flash,
    on_tpu,
    quantize_weights_int8,
    rglru_op,
)
from repro.kernels.paged_attention import paged_flash_attention, paged_mha

__all__ = [
    "default_interpret", "fake_quant_op", "linear_w8a8", "mha_flash",
    "on_tpu", "paged_flash_attention", "paged_mha", "quantize_weights_int8",
    "rglru_op",
]
