"""Pallas TPU kernels (validated in interpret mode on CPU; see ref.py)."""
from repro.kernels.ops import (
    default_interpret,
    fake_quant_op,
    linear_w8a8,
    mha_flash,
    on_tpu,
    quantize_weights_int8,
    rglru_op,
)

__all__ = [
    "default_interpret", "fake_quant_op", "linear_w8a8", "mha_flash",
    "on_tpu", "quantize_weights_int8", "rglru_op",
]
