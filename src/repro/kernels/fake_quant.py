"""Pallas fused fake-quant (Eq. 1): one pass over the tensor applying
quant-dequant with static (s, z). In a PTQ serving graph this op brackets
every matmul; fusing it keeps the activation tensor's HBM round-trips at
1 read + 1 write (it is purely memory-bound: arithmetic intensity ~5
flops/byte-pair, far below the v5e ridge point, so bandwidth IS the
roofline and the win is not re-materializing intermediates)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, s, z, n_levels):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x / s + z), 0.0, n_levels - 1.0)
    o_ref[...] = (s * (q - z)).astype(o_ref.dtype)


def fake_quant_pallas(x: jax.Array, s: float, z: float, bits: int = 8,
                      block: int = 1024, interpret: bool = True) -> jax.Array:
    """Per-tensor fake-quant; static python-float (s, z) baked into the
    kernel (the PTQ context provides them after calibration)."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = pl.pallas_call(
        functools.partial(_kernel, s=float(s), z=float(z), n_levels=2 ** bits),
        grid=(flat.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    return out[:n].reshape(orig_shape)
