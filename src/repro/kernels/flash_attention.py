"""Pallas TPU flash attention with clipped softmax + gated attention.

TPU adaptation of the paper's drop-in softmax replacement (DESIGN.md §3):
the clipped softmax needs the *globally normalized* probability before the
affine stretch+clip, which conflicts with single-pass online softmax (you
never hold the final (m, Z) while streaming). We therefore run TWO
streaming passes over KV blocks:

  pass 1 (``_mz_kernel``)  — classic online-softmax recurrence, emits the
      per-query (m, Z); O(T) memory, never materializes (Tq, Tk).
  pass 2 (``_av_kernel``)  — re-streams KV, forms
      p = clip((zeta-gamma) * exp(s-m)/Z + gamma, 0, 1) per block and
      accumulates p @ V in an f32 VMEM scratch.

Vanilla softmax (gamma=0, zeta=1) takes the standard single-pass kernel
with running rescale. The paper's per-(head, token) gate pi multiplies the
output tile in the epilogue (token-local, fuses for free).

Grid: (batch*heads, nQ, nKV); the KV dimension is sequential so VMEM
scratch carries across KV steps ("arbitrary" dimension semantics on TPU).
Blocks (block_q x d_head), (block_kv x d_head): multiples of 128 keep MXU
matmul dims aligned; VMEM working set per step = q + k + v blocks + acc
~= 4 * 128 * 256 * 4B ~ 0.5 MB at d_head=256 — far under the ~16 MB/core
budget, leaving headroom for the double-buffered pipeline.

Oracle: ``repro.kernels.ref.attention_ref`` (pure jnp); swept over shapes,
dtypes, masks and (gamma, zeta) in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(q_idx, kv_idx, block_q, block_kv, causal, window, q_offset, kv_len):
    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + q_offset
    k_pos = kv_idx * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def _masked_scores(q_ref, k_ref, scale, softcap, block_q, block_kv,
                   causal, window, q_offset, kv_len):
    s = jax.lax.dot_general(
        q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = _block_mask(pl.program_id(1), pl.program_id(2), block_q, block_kv,
                       causal, window, q_offset, kv_len)
    return jnp.where(mask, s, NEG_INF), mask


def _clean_v(v_ref, kv_idx, block_kv, kv_len):
    """Zero out-of-range V rows: block padding may be NaN (interpret mode
    fills OOB with NaN) and 0 * NaN = NaN in the p @ V accumulation."""
    valid = kv_idx * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, v_ref[0].shape, 0) < kv_len
    return jnp.where(valid, v_ref[0].astype(jnp.float32), 0.0)


def _mz_kernel(q_ref, k_ref, m_ref, z_ref, m_scr, z_scr, *, cfg):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)

    s, mask = _masked_scores(q_ref, k_ref, cfg["scale"], cfg["softcap"],
                             cfg["block_q"], cfg["block_kv"], cfg["causal"],
                             cfg["window"], cfg["q_offset"], cfg["kv_len"])
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    z_scr[...] = z_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(p, axis=-1)
    m_scr[...] = m_new

    @pl.when(kv_idx == cfg["n_kv"] - 1)
    def _():
        m_ref[0] = m_scr[...]
        z_ref[0] = z_scr[...]


def _av_kernel(q_ref, k_ref, v_ref, m_ref, z_ref, gate_ref, o_ref, acc_scr,
               *, cfg):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s, mask = _masked_scores(q_ref, k_ref, cfg["scale"], cfg["softcap"],
                             cfg["block_q"], cfg["block_kv"], cfg["causal"],
                             cfg["window"], cfg["q_offset"], cfg["kv_len"])
    m = m_ref[0]
    z = jnp.maximum(z_ref[0], 1e-30)
    p = jnp.exp(s - m[:, None]) / z[:, None]
    p = jnp.clip((cfg["zeta"] - cfg["gamma"]) * p + cfg["gamma"], 0.0, 1.0)
    p = jnp.where(mask, p, 0.0)
    acc_scr[...] += jax.lax.dot_general(
        p, _clean_v(v_ref, kv_idx, cfg["block_kv"], cfg["kv_len"]),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kv_idx == cfg["n_kv"] - 1)
    def _():
        out = acc_scr[...]
        if gate_ref is not None:
            out = out * gate_ref[0][:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def _vanilla_kernel(q_ref, k_ref, v_ref, gate_ref, o_ref, m_scr, z_scr,
                    acc_scr, *, cfg):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s, mask = _masked_scores(q_ref, k_ref, cfg["scale"], cfg["softcap"],
                             cfg["block_q"], cfg["block_kv"], cfg["causal"],
                             cfg["window"], cfg["q_offset"], cfg["kv_len"])
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    z_scr[...] = z_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, _clean_v(v_ref, kv_idx, cfg["block_kv"], cfg["kv_len"]),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kv_idx == cfg["n_kv"] - 1)
    def _():
        out = acc_scr[...] / jnp.maximum(z_scr[...], 1e-30)[:, None]
        if gate_ref is not None:
            out = out * gate_ref[0][:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (BH, Tq, Dh) — batch*heads flattened
    k: jax.Array,            # (BH, Tk, Dh)
    v: jax.Array,            # (BH, Tk, Dh)
    gate_pi: Optional[jax.Array] = None,    # (BH, Tq)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    gamma: float = 0.0,
    zeta: float = 1.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused multi-head attention; (gamma, zeta) = (0, 1) selects the
    single-pass vanilla path, anything else the two-pass clipped path."""
    bh, tq, dh = q.shape
    tk = k.shape[1]
    block_q = min(block_q, tq)
    block_kv = min(block_kv, tk)
    n_q = pl.cdiv(tq, block_q)
    n_kv = pl.cdiv(tk, block_kv)
    grid = (bh, n_q, n_kv)
    cfg = dict(block_q=block_q, block_kv=block_kv, scale=dh ** -0.5,
               causal=causal, window=window, softcap=softcap,
               q_offset=q_offset, kv_len=tk, n_kv=n_kv,
               gamma=gamma, zeta=zeta)

    q_spec = pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_kv, dh), lambda b, i, j: (b, j, 0))
    o_spec = pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0))
    mz_spec = pl.BlockSpec((1, block_q), lambda b, i, j: (b, i))
    has_gate = gate_pi is not None

    if gamma == 0.0 and zeta == 1.0:
        if has_gate:
            kern = functools.partial(_vanilla_kernel, cfg=cfg)
            in_specs = [q_spec, kv_spec, kv_spec, mz_spec]
            args = (q, k, v, gate_pi)
        else:
            kern = functools.partial(
                lambda qr, kr, vr, o, m, z, a, cfg: _vanilla_kernel(
                    qr, kr, vr, None, o, m, z, a, cfg=cfg), cfg=cfg)
            in_specs = [q_spec, kv_spec, kv_spec]
            args = (q, k, v)
        return pl.pallas_call(
            kern, grid=grid, in_specs=in_specs, out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((bh, tq, dh), q.dtype),
            scratch_shapes=[pltpu.VMEM((block_q,), jnp.float32),
                            pltpu.VMEM((block_q,), jnp.float32),
                            pltpu.VMEM((block_q, dh), jnp.float32)],
            interpret=interpret,
        )(*args)

    # ---- clipped softmax: 2 streaming passes ----
    m, z = pl.pallas_call(
        functools.partial(_mz_kernel, cfg=cfg),
        grid=grid,
        in_specs=[q_spec, kv_spec],
        out_specs=[mz_spec, mz_spec],
        out_shape=[jax.ShapeDtypeStruct((bh, tq), jnp.float32),
                   jax.ShapeDtypeStruct((bh, tq), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q,), jnp.float32)],
        interpret=interpret,
    )(q, k)

    if has_gate:
        kern = functools.partial(_av_kernel, cfg=cfg)
        in_specs = [q_spec, kv_spec, kv_spec, mz_spec, mz_spec, mz_spec]
        args = (q, k, v, m, z, gate_pi)
    else:
        kern = functools.partial(
            lambda qr, kr, vr, mr, zr, o, a, cfg: _av_kernel(
                qr, kr, vr, mr, zr, None, o, a, cfg=cfg), cfg=cfg)
        in_specs = [q_spec, kv_spec, kv_spec, mz_spec, mz_spec]
        args = (q, k, v, m, z)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((bh, tq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, dh), jnp.float32)],
        interpret=interpret,
    )(*args)
