"""Pallas TPU W8A8 matmul — the integer-compute payoff the paper's
architecture changes unlock.

On TPU the MXU natively consumes int8 operands with int32 accumulation
(~2x bf16 throughput on v5e). This kernel implements the paper's W8A8
scheme end-to-end:

  * activations: per-tensor *asymmetric* uint8 (scale s_x, zero-point z_x),
    quantized on the fly in the prologue of each block — legal BECAUSE the
    paper's clipped-softmax/gated-attention models have no outliers, so a
    static per-tensor range works (Table 2);
  * weights: per-tensor symmetric int8 (pre-quantized, scale s_w);
  * integer matmul with the zero-point folded out:
        (x_q - z_x) @ w_q = x_q @ w_q - z_x * colsum(w_q)
    accumulated in an int32... kept in f32 scratch here because interpret
    mode runs on CPU; the dot itself requests int32
    (``preferred_element_type``) exactly as the MXU path would;
  * epilogue: dequantize by s_x * s_w.

Grid (M/bm, N/bn, K/bk), K sequential with an accumulator scratch.
256x256x256 int8 blocks = 3 x 64 KB operands + 256 KB f32 accumulator,
comfortably double-buffered in VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xq_ref, wq_ref, o_ref, acc_scr, *, n_k, scale, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # int8 x int8 -> int32 (MXU-native); interpret mode emulates on CPU
    acc_scr[...] += jax.lax.dot_general(
        xq_ref[...].astype(jnp.int32), wq_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _():
        o_ref[...] = (acc_scr[...].astype(jnp.float32) * scale).astype(out_dtype)


def quantize_weights_int8(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 weight quantization (paper §C.4)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-8)
    wq = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127
                  ).astype(jnp.int8)
    return wq, scale


def int8_matmul(
    x: jax.Array,            # (M, K) float
    w_q: jax.Array,          # (K, N) int8 (symmetric)
    w_scale: jax.Array,      # scalar f32
    *,
    x_scale: float = None,   # static PTQ-calibrated activation scale
    x_zero: float = None,    # static activation zero-point (uint8 domain)
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Full W8A8 matmul: per-tensor asymmetric activation quant + integer
    kernel + dequant. Returns f32 (M, N).

    When ``x_scale``/``x_zero`` are given (PTQ-calibrated static ranges,
    e.g. from QuantContext.act_qparams) the dynamic min/max pass over x is
    skipped — the production serving configuration. Without them the range
    is derived from this batch (dynamic quantization)."""
    m, kdim = x.shape
    n = w_q.shape[1]
    # activation quantization (asymmetric uint8, zero-point folded out)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    x32 = x.astype(jnp.float32)
    if x_scale is None:
        x_min = jnp.minimum(jnp.min(x32), 0.0)
        x_max = jnp.maximum(jnp.max(x32), 0.0)
        s_x = jnp.maximum((x_max - x_min) / 255.0, 1e-8)
        z_x = jnp.clip(jnp.round(-x_min / s_x), 0, 255)
    else:
        s_x = jnp.float32(x_scale)
        z_x = jnp.float32(0.0 if x_zero is None else x_zero)
    # (q - z) has range [-255, 255]; real int8 pipelines keep the centered
    # value saturated to [-127, 127] (the paper's outlier-free activations
    # make saturation loss negligible — that is the point of the method).
    xq_c = jnp.clip(jnp.clip(jnp.round(x32 / s_x) + z_x, 0, 255) - z_x,
                    -127, 127).astype(jnp.int8)

    # zero-pad to block multiples: int blocks pad with garbage otherwise
    pad_m = (-m) % block_m
    pad_k = (-kdim) % block_k
    pad_n = (-n) % block_n
    if pad_m or pad_k:
        xq_c = jnp.pad(xq_c, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w_q = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))

    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n), pl.cdiv(kdim, block_k))
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2], scale=1.0, out_dtype=jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pad_m, n + pad_n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xq_c, w_q)
    return out[:m, :n] * (s_x * w_scale)
