"""Jit'd public wrappers around the Pallas kernels, with model-layout
adapters (the kernels use flattened (B*H, T, D) layouts).

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated against ref.py in interpret mode).
Model code opts in via ``ModelConfig``-level flags — see
``repro.core.attention`` for the XLA twin the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul, quantize_weights_int8
from repro.kernels.rg_lru import rglru_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "gamma", "zeta", "q_offset",
    "block_q", "block_kv"))
def mha_flash(
    q: jax.Array,            # (B, T, H, D)
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,
    gate_pi: Optional[jax.Array] = None,   # (B, T, H)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    gamma: float = 0.0,
    zeta: float = 1.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    """Model-layout adapter: GQA expand + (B,H) flatten + kernel."""
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    gf = None if gate_pi is None else gate_pi.transpose(0, 2, 1).reshape(b * h, t)
    out = flash_attention(qf, kf, vf, gf, causal=causal, window=window,
                          softcap=softcap, gamma=gamma, zeta=zeta,
                          q_offset=q_offset, block_q=block_q,
                          block_kv=block_kv, interpret=default_interpret())
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)


@jax.jit
def linear_w8a8(x: jax.Array, w_q: jax.Array, w_scale: jax.Array) -> jax.Array:
    """(..., K) x int8 (K, N) -> (..., N) f32 via the int8 MXU kernel."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = int8_matmul(x2, w_q, w_scale, interpret=default_interpret())
    return y.reshape(*lead, w_q.shape[1])


def fake_quant_op(x: jax.Array, s: float, z: float, bits: int = 8) -> jax.Array:
    return fake_quant_pallas(x, s, z, bits, interpret=default_interpret())


def rglru_op(a: jax.Array, b: jax.Array, h0=None):
    return rglru_pallas(a, b, h0, interpret=default_interpret())


__all__ = ["mha_flash", "linear_w8a8", "fake_quant_op", "rglru_op",
           "quantize_weights_int8", "on_tpu", "default_interpret"]
