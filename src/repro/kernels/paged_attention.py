"""Pallas TPU paged-attention decode kernel: in-place block-pool reads.

TPU twin of ``repro.core.attention.paged_attention``'s gather path,
specialized for serving decode (Tq = 1..small) over the paged KV cache
(vLLM/PagedAttention pattern). The gather path materializes every row's
virtual KV sequence — a (B, W*block_size, Hkv, Dh) tensor per layer per
tick — before attending; this kernel never does. K/V stay in the global
pools ``(num_blocks, block_size, Hkv, Dh)`` and each grid step DMAs ONE
physical pool block straight into VMEM, addressed through a scalar-
prefetched per-row block table (``pltpu.PrefetchScalarGridSpec``): the
table and per-row ``q_offset`` vector land in SMEM before the grid runs,
so the k/v BlockSpec index_map can read ``table[b, w]`` to pick the pool
block for logical entry ``w``. No gather, no virtual sequence, per-tick
HBM traffic proportional to blocks actually visited.

Grid: ``(B, Hkv, W)`` with the block-table dimension innermost and
sequential ("arbitrary" TPU semantics), so the f32 VMEM scratch carries the
online-softmax state across a row's blocks. All ``G = Hq/Hkv`` query heads
of one KV head are processed together as a (Tq*G, Dh) tile — the GQA twin
of the flash kernel's (block_q, d) tile, and the moral equivalent of
vLLM's head-packing (one pool block read serves the whole query group).

Semantics match the gather oracle exactly:

  * causal + local-window masks over *logical* positions built from the
    prefetched per-row ``q_offset`` (scalar or (B,) vector);
  * unallocated table entries (id < 0) contribute nothing (the index_map
    clamps the pool read to a safe block, the kernel masks it out);
  * logit soft-capping;
  * vanilla softmax = single online pass; the paper's clipped softmax =
    the same TWO streaming passes as ``kernels/flash_attention.py``
    (pass 1 emits the per-query (m, Z), pass 2 re-streams the blocks and
    accumulates clip((zeta-gamma)·p + gamma, 0, 1) @ V). ``gamma`` must be
    resolved by the caller from the LOGICAL ``max_len`` (the dispatcher in
    ``core.attention`` does this) so clipping thresholds are invariant to
    how many blocks happen to be live;
  * the per-head gate ``pi`` multiplies the output tile in the epilogue;
  * int8 pools (``init_paged_cache(kv_int8=True)``): the per-slot scale
    vectors ``k_scale``/``v_scale`` ((NB, BS) f32) ride the SAME
    table-driven BlockSpec index_map as their pool block — each grid step
    DMAs the block's (BS,) scale row next to its (BS, Hkv, Dh) payload and
    dequantizes in the epilogue of the load (``k * ks[:, None]``), so the
    streaming softmax only ever sees fp tiles. Stale scales in recycled
    blocks are masked exactly like stale KV.

Accumulation is f32 blockwise streaming, so results match the gather
oracle to f32 round-off of the differing reduction order (~1 ulp per
accumulated block; tests assert atol=2e-5 f32 / 2e-2 bf16), not bitwise.

Oracle: ``paged_attention(..., backend="gather")``; swept over dtypes, GQA
ratios, masks, (gamma, zeta), ragged per-row positions and partial tail
blocks in tests/test_paged_kernel.py (interpret mode on CPU; TPU is the
target).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _scores(tbl_ref, off_ref, q_ref, k_ref, ks_ref, *, cfg):
    """(Tq*G, BS) masked scores of one (row, kv-head, table-entry) step."""
    b, h, w = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)               # (Tq*G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (BS, Dh)
    if ks_ref is not None:                            # int8 pool: dequant in
        k = k * ks_ref[0][:, None]                    # the DMA epilogue
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * cfg["scale"]
    if cfg["softcap"] is not None:
        s = cfg["softcap"] * jnp.tanh(s / cfg["softcap"])
    tq_g, bs = cfg["tq_g"], cfg["block_size"]
    # query row r serves head-group lane r % G of query token r // G
    q_pos = off_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, (tq_g, bs), 0) // cfg["group"]
    k_pos = w * bs + jax.lax.broadcasted_iota(jnp.int32, (tq_g, bs), 1)
    mask = jnp.full((tq_g, bs), tbl_ref[b, w] >= 0)   # unallocated entry
    if cfg["causal"]:
        mask &= k_pos <= q_pos
    if cfg["window"] is not None:
        mask &= k_pos > q_pos - cfg["window"]
    return jnp.where(mask, s, NEG_INF), mask


def _vblock(v_ref, vs_ref):
    """One pool block's V tile, dequantized if the pool is int8."""
    v = v_ref[0, :, 0].astype(jnp.float32)            # (BS, Dh)
    if vs_ref is not None:
        v = v * vs_ref[0][:, None]
    return v


def _vanilla_kernel(tbl_ref, off_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                    gate_ref, o_ref, m_scr, z_scr, acc_scr, *, cfg):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s, mask = _scores(tbl_ref, off_ref, q_ref, k_ref, ks_ref, cfg=cfg)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    z_scr[...] = z_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, _vblock(v_ref, vs_ref),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(w == cfg["n_w"] - 1)
    def _():
        out = acc_scr[...] / jnp.maximum(z_scr[...], 1e-30)[:, None]
        if gate_ref is not None:
            out = out * gate_ref[0, 0][:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _mz_kernel(tbl_ref, off_ref, q_ref, k_ref, ks_ref, m_ref, z_ref,
               m_scr, z_scr, *, cfg):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)

    s, mask = _scores(tbl_ref, off_ref, q_ref, k_ref, ks_ref, cfg=cfg)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    z_scr[...] = z_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(p, axis=-1)
    m_scr[...] = m_new

    @pl.when(w == cfg["n_w"] - 1)
    def _():
        m_ref[0, 0] = m_scr[...]
        z_ref[0, 0] = z_scr[...]


def _av_kernel(tbl_ref, off_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
               m_ref, z_ref, gate_ref, o_ref, acc_scr, *, cfg):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s, mask = _scores(tbl_ref, off_ref, q_ref, k_ref, ks_ref, cfg=cfg)
    m = m_ref[0, 0]
    z = jnp.maximum(z_ref[0, 0], 1e-30)
    p = jnp.exp(s - m[:, None]) / z[:, None]
    p = jnp.clip((cfg["zeta"] - cfg["gamma"]) * p + cfg["gamma"], 0.0, 1.0)
    p = jnp.where(mask, p, 0.0)
    acc_scr[...] += jax.lax.dot_general(
        p, _vblock(v_ref, vs_ref),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(w == cfg["n_w"] - 1)
    def _():
        out = acc_scr[...]
        if gate_ref is not None:
            out = out * gate_ref[0, 0][:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_flash_attention(
    q: jax.Array,            # (B, Hkv, Tq*G, Dh) — head-grouped queries
    k_pool: jax.Array,       # (NB, BS, Hkv, Dh) — global block pool
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, W) int32 physical block ids, -1 = unalloc
    q_off: jax.Array,        # (B,) int32 logical position of query row 0
    gate_pi: Optional[jax.Array] = None,    # (B, Hkv, Tq*G)
    *,
    group: int = 1,          # G = Hq // Hkv (query rows per logical token)
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    gamma: float = 0.0,
    zeta: float = 1.0,
    k_scale: Optional[jax.Array] = None,    # (NB, BS) f32 per-slot scales
    v_scale: Optional[jax.Array] = None,
    interpret: bool = True,
) -> jax.Array:
    """Fused paged attention; (gamma, zeta) = (0, 1) selects the single-pass
    vanilla path, anything else the two-pass clipped path. ``gamma`` must
    already be resolved from the logical max_len (see module docstring).
    ``k_scale``/``v_scale`` mark the pools as int8: each grid step DMAs the
    visited block's scale row alongside it and dequantizes on load."""
    b, hkv, tq_g, dh = q.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    w = block_table.shape[1]
    grid = (b, hkv, w)
    cfg = dict(scale=dh ** -0.5, causal=causal, window=window,
               softcap=softcap, gamma=gamma, zeta=zeta, n_w=w,
               tq_g=tq_g, block_size=bs, group=group)

    table = block_table.astype(jnp.int32)
    off = q_off.astype(jnp.int32)

    # the index_map receives (grid ids..., scalar-prefetch refs...); the
    # clamp keeps unallocated (-1) entries a safe in-range DMA — the kernel
    # masks their contribution out via tbl_ref[b, w] >= 0
    def kv_index(bi, hi, wi, tbl, _off):
        return (jnp.clip(tbl[bi, wi], 0, nb - 1), 0, hi, 0)

    # int8 pools: the per-slot scale row of the visited block rides the same
    # table-driven indirection — one (BS,) f32 vector per block DMA
    def sc_index(bi, hi, wi, tbl, _off):
        return (jnp.clip(tbl[bi, wi], 0, nb - 1), 0)

    q_spec = pl.BlockSpec((1, 1, tq_g, dh),
                          lambda bi, hi, wi, tbl, off_: (bi, hi, 0, 0))
    kv_spec = pl.BlockSpec((1, bs, 1, dh), kv_index)
    sc_spec = pl.BlockSpec((1, bs), sc_index)
    o_spec = pl.BlockSpec((1, 1, tq_g, dh),
                          lambda bi, hi, wi, tbl, off_: (bi, hi, 0, 0))
    mz_spec = pl.BlockSpec((1, 1, tq_g),
                           lambda bi, hi, wi, tbl, off_: (bi, hi, 0))
    has_gate = gate_pi is not None
    quantized = k_scale is not None
    if quantized:
        k_scale = k_scale.astype(jnp.float32)
        v_scale = v_scale.astype(jnp.float32)

    def call(kern, in_specs, args, out_specs, out_shape, scratch):
        return pl.pallas_call(
            kern,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=grid,
                in_specs=in_specs,
                out_specs=out_specs,
                scratch_shapes=scratch,
            ),
            out_shape=out_shape,
            interpret=interpret,
        )(table, off, *args)

    # optional inputs (scale rows, gate) are appended positionally; each
    # entry adapter peels the refs present for this configuration and calls
    # the kernel with None for the absent ones (quantized/has_gate are
    # trace-time constants, so the kernels specialize cleanly)
    if gamma == 0.0 and zeta == 1.0:
        in_specs = [q_spec, kv_spec, kv_spec]
        args = [q, k_pool, v_pool]
        if quantized:
            in_specs += [sc_spec, sc_spec]
            args += [k_scale, v_scale]
        if has_gate:
            in_specs += [mz_spec]
            args += [gate_pi]

        def vanilla_entry(t, of, *rest):
            it = iter(rest)
            qr, kr, vr = next(it), next(it), next(it)
            ks, vs = (next(it), next(it)) if quantized else (None, None)
            gr = next(it) if has_gate else None
            o, m, z, a = next(it), next(it), next(it), next(it)
            _vanilla_kernel(t, of, qr, kr, vr, ks, vs, gr, o, m, z, a,
                            cfg=cfg)

        return call(
            vanilla_entry, in_specs, args, o_spec,
            jax.ShapeDtypeStruct((b, hkv, tq_g, dh), q.dtype),
            [pltpu.VMEM((tq_g,), jnp.float32),
             pltpu.VMEM((tq_g,), jnp.float32),
             pltpu.VMEM((tq_g, dh), jnp.float32)])

    # ---- clipped softmax: 2 streaming passes over the block table ----
    def mz_entry(t, of, *rest):
        it = iter(rest)
        qr, kr = next(it), next(it)
        ks = next(it) if quantized else None
        mr, zr, ms, zs = next(it), next(it), next(it), next(it)
        _mz_kernel(t, of, qr, kr, ks, mr, zr, ms, zs, cfg=cfg)

    m, z = call(
        mz_entry,
        [q_spec, kv_spec] + ([sc_spec] if quantized else []),
        [q, k_pool] + ([k_scale] if quantized else []),
        [mz_spec, mz_spec],
        [jax.ShapeDtypeStruct((b, hkv, tq_g), jnp.float32),
         jax.ShapeDtypeStruct((b, hkv, tq_g), jnp.float32)],
        [pltpu.VMEM((tq_g,), jnp.float32),
         pltpu.VMEM((tq_g,), jnp.float32)])

    in_specs = [q_spec, kv_spec, kv_spec]
    args = [q, k_pool, v_pool]
    if quantized:
        in_specs += [sc_spec, sc_spec]
        args += [k_scale, v_scale]
    in_specs += [mz_spec, mz_spec]
    args += [m, z]
    if has_gate:
        in_specs += [mz_spec]
        args += [gate_pi]

    def av_entry(t, of, *rest):
        it = iter(rest)
        qr, kr, vr = next(it), next(it), next(it)
        ks, vs = (next(it), next(it)) if quantized else (None, None)
        mr, zr = next(it), next(it)
        gr = next(it) if has_gate else None
        o, a = next(it), next(it)
        _av_kernel(t, of, qr, kr, vr, ks, vs, mr, zr, gr, o, a, cfg=cfg)

    return call(
        av_entry, in_specs, args, o_spec,
        jax.ShapeDtypeStruct((b, hkv, tq_g, dh), q.dtype),
        [pltpu.VMEM((tq_g, dh), jnp.float32)])


def paged_mha(
    q: jax.Array,            # (B, Tq, Hq, Dh) — model layout
    k_pool: jax.Array,       # (NB, BS, Hkv, Dh)
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, W)
    q_offset=0,              # scalar or per-row (B,) int32
    gate_pi: Optional[jax.Array] = None,    # (B, Tq, Hq)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    gamma: float = 0.0,
    zeta: float = 1.0,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Model-layout adapter: head-group the queries (all G query heads of a
    KV head share one pool-block read) and invoke the kernel. Returns
    (B, Tq, Hq, Dh) like ``dense_attention``."""
    b, tq, hq, dh = q.shape
    hkv = k_pool.shape[2]
    g = hq // hkv
    qf = q.reshape(b, tq, hkv, g, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hkv, tq * g, dh)
    gf = None
    if gate_pi is not None:
        gf = gate_pi.reshape(b, tq, hkv, g).transpose(0, 2, 1, 3) \
            .reshape(b, hkv, tq * g)
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = paged_flash_attention(
        qf, k_pool, v_pool, block_table, off, gf, group=g, causal=causal,
        window=window, softcap=softcap, gamma=gamma, zeta=zeta,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret)
    return out.reshape(b, hkv, tq, g, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(b, tq, hq, dh)
