"""Pure-jnp oracles for every Pallas kernel. Slow, obviously-correct,
materializing implementations — the tests sweep shapes/dtypes and assert
allclose against these."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, gate_pi=None, *, causal=True, window=None,
                  softcap=None, gamma=0.0, zeta=1.0, q_offset=0):
    """(BH, Tq, Dh) x (BH, Tk, Dh) -> (BH, Tq, Dh). Materializes (Tq, Tk)."""
    bh, tq, dh = q.shape
    tk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(tq)[:, None] + q_offset
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    if not (gamma == 0.0 and zeta == 1.0):
        p = jnp.clip((zeta - gamma) * p + gamma, 0.0, 1.0)
        p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    if gate_pi is not None:
        out = out * gate_pi.astype(jnp.float32)[..., None]
    return out.astype(q.dtype)


def int8_matmul_ref(x, w_q, w_scale, *, x_bits=8):
    """W8A8 matmul oracle: dynamic per-tensor asymmetric activation
    quantization, symmetric int8 weights.

    x: (M, K) float; w_q: (K, N) int8; w_scale: scalar f32.
    Returns (M, N) f32 = dequant(q(x)) @ (w_q * w_scale)."""
    n = 2 ** x_bits
    x32 = x.astype(jnp.float32)
    x_min = jnp.minimum(jnp.min(x32), 0.0)
    x_max = jnp.maximum(jnp.max(x32), 0.0)
    s = jnp.maximum((x_max - x_min) / (n - 1), 1e-8)
    z = jnp.clip(jnp.round(-x_min / s), 0, n - 1)
    xq = jnp.clip(jnp.round(x32 / s) + z, 0, n - 1) - z   # integer grid, f32
    xq = jnp.clip(xq, -127, 127)                          # int8 saturation
    return (xq * s) @ (w_q.astype(jnp.float32) * w_scale)


def fake_quant_ref(x, s, z, bits=8):
    """Eq. 1 fake-quant oracle (per-tensor)."""
    n = 2 ** bits
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s + z), 0, n - 1)
    return (s * (q - z)).astype(x.dtype)


def rglru_ref(a, b, h0=None):
    """Sequential linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (B, T, D) f32; h0 (B, D) or None. Returns (h (B,T,D), h_last)."""
    bsz, t, d = a.shape
    h = jnp.zeros((bsz, d), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    outs = []
    for i in range(t):
        h = a[:, i] * h + b[:, i]
        outs.append(h)
    hs = jnp.stack(outs, axis=1)
    return hs, h
