"""Pallas TPU RG-LRU linear-recurrence kernel (recurrentgemma's mixer).

h_t = a_t * h_{t-1} + b_t, elementwise-diagonal — purely memory-bound
(2 loads + 1 store per element, zero matmuls). The XLA path uses
``associative_scan`` (log-depth, but 3x the HBM traffic from tree
intermediates); this kernel streams time sequentially while the recurrent
state lives in VMEM, hitting the 1-read-1-write minimum. Griffin's GPU
implementation makes the same trade (their "linear scan" kernel); this is
the TPU equivalent.

Grid (B, D/bd): each program owns a (T, bd) strip; time runs in a
fori_loop over VMEM-resident blocks. The feature dim is blocked at 512
lanes so (a, b, h) strips fit VMEM for T up to ~8k per call; longer
sequences chunk at the ops.py level, carrying h across calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref, *, t_len):
    h = h0_ref[0]                                  # (bd,)

    def body(i, h):
        h_new = a_ref[0, i, :] * h + b_ref[0, i, :]
        h_ref[0, i, :] = h_new.astype(h_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, t_len, body, h)
    hlast_ref[0] = h


def rglru_pallas(a: jax.Array, b: jax.Array, h0=None, *,
                 block_d: int = 512, interpret: bool = True):
    """a, b: (B, T, D) f32; h0: (B, D) or None.
    Returns (h (B,T,D) f32, h_last (B,D))."""
    bsz, t, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, d), jnp.float32)
    block_d = min(block_d, d)
    pad_d = (-d) % block_d
    if pad_d:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_d)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d)))
    dp = d + pad_d
    grid = (bsz, dp // block_d)
    h, hlast = pl.pallas_call(
        functools.partial(_kernel, t_len=t),
        grid=grid,
        in_specs=[pl.BlockSpec((1, t, block_d), lambda i, j: (i, 0, j)),
                  pl.BlockSpec((1, t, block_d), lambda i, j: (i, 0, j)),
                  pl.BlockSpec((1, block_d), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((1, t, block_d), lambda i, j: (i, 0, j)),
                   pl.BlockSpec((1, block_d), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((bsz, t, dp), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, dp), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))
    return h[..., :d], hlast[..., :d]
