"""Launchers: mesh construction, multi-pod dry-run, production train CLI.

NOTE: ``repro.launch.dryrun`` must be imported FIRST in a fresh process
(it sets XLA_FLAGS for 512 host devices before jax initializes).
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
