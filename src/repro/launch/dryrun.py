import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_FLAGS_EXTRA", "") +
    " --xla_force_host_platform_device_count=512"
)
"""Multi-pod dry-run: AOT lower + compile every (architecture x input shape)
cell on the production meshes, and extract roofline terms.

MUST be the first importer of jax in the process (XLA_FLAGS above is set
before any other import — jax locks the device count at first init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Roofline-term fidelity: XLA's ``cost_analysis`` counts a while-loop (scan)
body ONCE regardless of trip count, so a scanned-layers model under-reports
FLOPs/bytes/collectives by ~n_groups x. The full scanned config is still
compiled — that is the pass/fail artifact and the source of
``memory_analysis`` — but the roofline terms come from a two-point
extrapolation over UNROLLED reduced-depth twins (1 group + tail, 2 groups +
tail):  total(G) = (2*c1 - c2) + G*(c2 - c1), exact for homogeneous groups.

Results land in experiments/dryrun/<cell>.json — EXPERIMENTS.md §Dry-run
and §Roofline read from those.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, apply_method, cache_specs, get_arch, input_specs, list_archs
from repro.distributed.sharding import batch_specs, cache_specs_tree, tree_param_specs
from repro.launch.mesh import make_production_mesh, compat_set_mesh
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, analyze, model_flops_infer, model_flops_train, normalize_cost_analysis, parse_collectives
from repro.models.transformer import ModelConfig, model_init
from repro.nn.module import flatten_params
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainTask, init_train_state, make_decode_step, make_prefill_step, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def active_param_count(cfg: ModelConfig) -> int:
    """Parameter count weighted by MoE activation (top_k/n_experts) —
    feeds MODEL_FLOPS = 6*N_active*D."""
    shapes = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    total = 0
    for path, leaf in flatten_params(shapes):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        if "/moe/w_" in f"/{path}":
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


def param_count_full(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    total = 0
    for _, leaf in flatten_params(shapes):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n
    return total


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_lowered(cfg: ModelConfig, shape, mesh, profile: str,
                  microbatch: int = 1):
    """Construct the jitted step + ShapeDtypeStruct args for one cell and
    return the lowered module."""
    batch = input_specs(cfg, shape)
    if shape.step == "train":
        task = TrainTask(cfg=cfg, loss_kind="clm" if cfg.causal else "frames",
                         optimizer=AdamWConfig(), microbatch=microbatch)
        state_shapes = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), task))
        state_specs = tree_param_specs(state_shapes, profile, mesh)
        bspecs = batch_specs(batch, mesh)
        jitted = jax.jit(make_train_step(task),
                         in_shardings=(_ns(mesh, state_specs), _ns(mesh, bspecs)),
                         out_shardings=(_ns(mesh, state_specs), None),
                         donate_argnums=(0,))
        with compat_set_mesh(mesh):
            return jitted.lower(state_shapes, batch)
    params_shapes = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    pspecs = tree_param_specs(params_shapes, profile, mesh)
    if shape.step == "prefill":
        # "tp_seq": context parallelism — sequence over the model axis,
        # weights tp_only; MLPs become token-parallel (no activation AR),
        # attention gathers KV per layer instead.
        bspecs = batch_specs(batch, mesh, shard_seq=profile == "tp_seq",
                             seq_axis="model")
        jitted = jax.jit(make_prefill_step(cfg),
                         in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)))
        with compat_set_mesh(mesh):
            return jitted.lower(params_shapes, batch)
    # decode
    cache_shapes = cache_specs(cfg, shape)
    cspecs = cache_specs_tree(cache_shapes, mesh, cfg, shape.global_batch)
    tok = batch["tokens"]
    n_dp = 1
    for ax in mesh.axis_names:
        if ax != "model":
            n_dp *= mesh.shape[ax]
    tok_spec = batch_specs({"tokens": tok}, mesh)["tokens"] \
        if shape.global_batch % n_dp == 0 else P()
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(
        make_decode_step(cfg),
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                      NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())),
        out_shardings=(None, _ns(mesh, cspecs)),
        donate_argnums=(1,),
    )
    with compat_set_mesh(mesh):
        return jitted.lower(params_shapes, cache_shapes, tok, pos)


def _cost_triple(compiled) -> Tuple[float, float, float]:
    ca = normalize_cost_analysis(compiled)
    colls = parse_collectives(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            colls.wire_bytes)


def extrapolated_costs(cfg: ModelConfig, shape, mesh, profile: str
                       ) -> Dict[str, float]:
    """Two-point unrolled extrapolation of (flops, hbm, wire) per device.

    Depths 2 and 3 (not 1 and 2): the 1-group module shows boundary
    effects — XLA hoists/CSEs collectives differently when a tensor is
    used once — which can make the naive slope negative."""
    glen = len(cfg.pattern)
    tail = cfg.n_layers % glen
    g_full = cfg.n_groups
    k_lo, k_hi = (2, 3) if g_full >= 3 else (1, 2)
    cfgs = [dataclasses.replace(cfg, n_layers=glen * k + tail,
                                scan_layers=False)
            for k in (k_lo, k_hi)]
    c_lo = _cost_triple(build_lowered(cfgs[0], shape, mesh, profile).compile())
    c_hi = _cost_triple(build_lowered(cfgs[1], shape, mesh, profile).compile())
    out = {}
    for name, a, b in zip(("flops", "hbm_bytes", "wire_bytes"), c_lo, c_hi):
        per_group = max(b - a, 0.0)
        fixed = max(a - k_lo * per_group, 0.0)
        out[name] = fixed + g_full * per_group
        out[name + "_per_group"] = per_group
    return out


def lower_cell(arch_id: str, shape_name: str, mesh, profile: str = "tp_fsdp",
               method: str = "clipped_softmax", microbatch: int = 1,
               skip_extrapolation: bool = False,
               moe_exec: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one cell; return a JSON-serializable report."""
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    why = spec.skipped(shape_name)
    if why is not None:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": why}

    cfg = apply_method(spec.full(), method)
    cfg = dataclasses.replace(
        cfg, max_seq_len=max(shape.seq_len + 8, cfg.window or 0))
    if moe_exec and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, exec_mode=moe_exec))
    n_chips = mesh.devices.size
    report: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "mesh": list(mesh.shape.values()),
        "profile": profile, "method": method, "status": "ok",
    }

    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, profile, microbatch)
    report["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t1, 2)

    if shape.step == "train":
        n_tokens = shape.global_batch * shape.seq_len
        mf = model_flops_train(active_param_count(cfg), n_tokens)
    elif shape.step == "prefill":
        mf = model_flops_infer(active_param_count(cfg),
                               shape.global_batch * shape.seq_len)
    else:
        mf = model_flops_infer(active_param_count(cfg), shape.global_batch)

    roof = analyze(compiled, n_chips, model_flops_total=mf)
    report["roofline_scanned_raw"] = roof.as_dict()

    if not skip_extrapolation and cfg.scan_layers and cfg.n_groups > 2:
        t2 = time.time()
        ext = extrapolated_costs(cfg, shape, mesh, profile)
        report["extrapolate_s"] = round(time.time() - t2, 2)
        terms = {
            "flops_per_device": ext["flops"],
            "hbm_bytes_per_device": ext["hbm_bytes"],
            "wire_bytes_per_device": ext["wire_bytes"],
            "compute_s": ext["flops"] / PEAK_FLOPS,
            "memory_s": ext["hbm_bytes"] / HBM_BW,
            "collective_s": ext["wire_bytes"] / ICI_BW,
        }
        terms["bottleneck"] = max(
            ("compute", "memory", "collective"),
            key=lambda k: terms[k + "_s" if k != "collective" else "collective_s"])
        terms["model_flops"] = mf / n_chips
        terms["useful_flops_ratio"] = (
            (mf / n_chips) / terms["flops_per_device"]
            if terms["flops_per_device"] else None)
        terms["memory_stats"] = roof.memory_stats
        report["roofline"] = terms
    else:
        report["roofline"] = roof.as_dict()

    report["params_total"] = param_count_full(cfg)
    report["params_active"] = active_param_count(cfg)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--profile", default="tp_fsdp")
    ap.add_argument("--method", default="clipped_softmax")
    ap.add_argument("--moe-exec", default=None, choices=[None, "dense", "dispatch"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="skip cost extrapolation (pass/fail only)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_tag = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shp in shapes:
                tag = f"{arch}__{shp}__{mesh_tag}__{args.profile}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rep = lower_cell(arch, shp, mesh, args.profile, args.method,
                                     skip_extrapolation=args.fast,
                                     moe_exec=args.moe_exec)
                except Exception as e:  # noqa: BLE001 — report and continue
                    rep = {"arch": arch, "shape": shp, "mesh": mesh_tag,
                           "status": "error", "error": str(e)[:2000],
                           "traceback": traceback.format_exc()[-3000:]}
                rep["mesh_tag"] = mesh_tag
                with open(path, "w") as f:
                    json.dump(rep, f, indent=1)
                st = rep["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                extra = ""
                if st == "ok":
                    r = rep["roofline"]
                    extra = (f"bottleneck={r['bottleneck']} "
                             f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
                             f"x={r['collective_s']:.3f}s "
                             f"lower={rep['lower_s']}s compile={rep['compile_s']}s")
                elif st == "error":
                    extra = rep["error"].splitlines()[0][:140] if rep["error"] else ""
                print(f"[{st:7s}] {tag} {extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
