"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets XLA_FLAGS before first
jax init and only then calls these.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

try:  # newer jax exposes explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def compat_make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` across jax versions: passes ``axis_types`` (all
    Auto) when the installed jax has ``jax.sharding.AxisType``, and falls
    back to the plain call otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def compat_set_mesh(mesh: Mesh):
    """``jax.sharding.set_mesh(mesh)`` where available; on older jax, enter
    the mesh itself (legacy resource-env context). Either way usable as
    ``with compat_set_mesh(mesh): ...`` around tracing/lowering."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256-chip single pod, or 2x16x16 = 512-chip two-pod mesh.

    Axis order puts 'pod' outermost (slowest links — DCI), then 'data'
    (intra-pod DP/FSDP), then 'model' (TP/EP, fastest ICI neighbours).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist locally (tests / CPU smoke): (1, n)."""
    n = len(jax.devices())
    return compat_make_mesh((1, n), ("data", "model"))
