"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets XLA_FLAGS before first
jax init and only then calls these.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256-chip single pod, or 2x16x16 = 512-chip two-pod mesh.

    Axis order puts 'pod' outermost (slowest links — DCI), then 'data'
    (intra-pod DP/FSDP), then 'model' (TP/EP, fastest ICI neighbours).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """Whatever devices exist locally (tests / CPU smoke): (1, n)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
