"""Roofline-term extraction from AOT-compiled modules.

Hardware model (TPU v5e-class, per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s/link

The compiled HLO is the *partitioned* (per-device) module, so
``cost_analysis()`` FLOPs/bytes and the collective shapes parsed from
``as_text()`` are per-device quantities. The three roofline terms are
therefore per-device seconds (equivalent to aggregate / (chips x rate)):

    compute    = flops_per_device / PEAK_FLOPS
    memory     = hbm_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW

Wire bytes use ring-algorithm multipliers derived from the parsed
``replica_groups`` size S:
    all-reduce        2 (S-1)/S x buffer
    all-gather          (S-1)/S x gathered result
    reduce-scatter      (S-1)/S x input        (= result x S x (S-1)/S)
    all-to-all          (S-1)/S x buffer
    collective-permute  1        x buffer
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9,\[\]\{\}\s]+?)(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|s32|u32|s64|u64|f16|bf16|f32|f64|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE2 = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE2.search(line)
    if m:
        first = m.group(1).split("}")[0].split(",")
        return max(len(first), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    buffer_bytes: Dict[str, float]   # per-device buffer bytes by op kind
    wire_bytes: float                # ring-model bytes on the wire / device

    def as_dict(self):
        return {"counts": self.counts, "buffer_bytes": self.buffer_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str, default_group: int = 16) -> CollectiveStats:
    counts: Dict[str, int] = {}
    buf: Dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue   # async pairs counted at -start
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_types, kind = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(result_types)
        if nbytes == 0:
            continue
        s = _group_size(line, default_group)
        frac = (s - 1) / max(s, 1)
        if kind == "all-reduce":
            w = 2.0 * frac * nbytes
        elif kind == "all-gather":
            w = frac * nbytes                     # result is gathered size
        elif kind == "reduce-scatter":
            w = frac * nbytes * s                 # input = result x S
        elif kind == "all-to-all":
            w = frac * nbytes
        else:                                      # collective-permute
            w = float(nbytes)
        counts[kind] = counts.get(kind, 0) + 1
        buf[kind] = buf.get(kind, 0.0) + nbytes
        wire += w
    return CollectiveStats(counts, buf, wire)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None       # 6*N*D (per device share)
    useful_flops_ratio: Optional[float] = None
    collectives: Optional[dict] = None
    memory_stats: Optional[dict] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def normalize_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions (older jax returns
    one dict per device in a list)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze(compiled, n_chips: int,
            model_flops_total: Optional[float] = None) -> Roofline:
    """Build the three-term roofline from one compiled executable."""
    ca = normalize_cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    colls = parse_collectives(txt)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = colls.wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ms = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": int(ms.argument_size_in_bytes),
        "output_bytes": int(ms.output_size_in_bytes),
        "temp_bytes": int(ms.temp_size_in_bytes),
        "code_bytes": int(ms.generated_code_size_in_bytes),
    }
    r = Roofline(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        wire_bytes_per_device=colls.wire_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        collectives=colls.as_dict(),
        memory_stats=mem_stats,
    )
    if model_flops_total:
        per_dev = model_flops_total / n_chips
        r.model_flops = per_dev
        r.useful_flops_ratio = per_dev / flops if flops else None
    return r


def model_flops_train(n_params_active: int, n_tokens: int) -> float:
    """6*N*D rule (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_infer(n_params_active: int, n_tokens: int) -> float:
    return 2.0 * n_params_active * n_tokens
