"""Production training launcher: mesh + sharded train step + checkpointed
loop. On the CPU container it runs real (small) configs on the host mesh;
on a TPU fleet the same entrypoint spans pods (jax.distributed initializes
from the cluster env; the mesh/profile flags pick the parallelism layout).

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 50 --profile tp_fsdp

Distributed-optimization flags map to §Perf levers:
  --profile tp_fsdp|tp_only|replicated   weight sharding layout
  --microbatch N                         gradient accumulation
  --grad-compress                        int8 DP all-reduce + error feedback
  --remat / --no-remat                   activation checkpointing
XLA latency-hiding scheduler flags (compute/comm overlap) are applied via
REPRO_XLA_FLAGS_EXTRA before jax init.
"""
import os

_EXTRA = os.environ.get("REPRO_XLA_FLAGS_EXTRA")
if _EXTRA:
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " " + _EXTRA

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import apply_method, get_arch
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.distributed.sharding import batch_specs, tree_param_specs
from repro.launch.mesh import make_host_mesh, make_production_mesh, compat_set_mesh
from repro.optim import AdamWConfig, linear_warmup_linear_decay
from repro.train.step import TrainTask, init_train_state, make_train_step


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--method", default="clipped_softmax")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--profile", default="tp_fsdp")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")

    spec = get_arch(args.arch)
    cfg = spec.smoke() if args.smoke else spec.full()
    cfg = apply_method(cfg, args.method)
    cfg = dataclasses.replace(cfg, remat=not args.no_remat,
                              max_seq_len=max(cfg.max_seq_len, args.seq_len))
    loss_kind = "clm" if cfg.causal else "frames"

    task = TrainTask(
        cfg=cfg, loss_kind=loss_kind,
        optimizer=AdamWConfig(lr=args.lr),
        schedule=linear_warmup_linear_decay(args.steps // 10, args.steps),
        microbatch=args.microbatch, grad_compress=args.grad_compress)

    with compat_set_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(0), task)
        state_specs = tree_param_specs(state, args.profile, mesh)
        state = jax.device_put(state, _ns(mesh, state_specs))
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(args.ckpt_dir, state)
            print(f"[resume] step {start}")

        data = SyntheticLMConfig(vocab_size=cfg.vocab_size,
                                 seq_len=args.seq_len,
                                 batch_size=args.batch_size)
        pipe = SyntheticLM(data)
        bspecs = None
        step_fn = jax.jit(make_train_step(task),
                          in_shardings=(_ns(mesh, state_specs), None),
                          out_shardings=(_ns(mesh, state_specs), None),
                          donate_argnums=(0,))

        import time
        durs = []
        for step in range(start, args.steps):
            batch = jax.tree_util.tree_map(jnp.asarray,
                                           pipe.batch(step, loss_kind))
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            metrics["loss"].block_until_ready()
            durs.append(time.perf_counter() - t0)
            if (step + 1) % max(args.steps // 10, 1) == 0:
                print(f"step {step+1:6d} loss {float(metrics['loss']):.4f} "
                      f"{durs[-1]*1e3:.0f}ms")
            if args.ckpt_every and args.ckpt_dir and \
                    (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1, state)
        print(f"median step {np.median(durs)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
