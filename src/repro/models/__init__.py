from repro.models.transformer import ModelConfig, init_cache, model_apply, model_init

__all__ = ["ModelConfig", "init_cache", "model_apply", "model_init"]
