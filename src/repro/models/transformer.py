"""Composable transformer covering the whole assigned architecture pool.

One ``ModelConfig`` describes dense GQA transformers (deepseek/qwen/codeqwen),
gemma-2 (alternating local/global attention + logit soft-caps + sandwich
norms), MoE transformers (granite, qwen2-moe), the Griffin hybrid
(recurrentgemma), xLSTM stacks, encoder-only audio (hubert) and
VLM/text backbones (phi-3-vision) — plus the paper's own BERT/OPT/ViT-style
models. The paper's knobs (``softmax_cfg``, ``gate_cfg``) apply to every
softmax-attention block.

Layer-group execution: ``pattern`` lists the block kinds of one group (e.g.
("rec", "rec", "attn") for recurrentgemma); the model scans over
``n_layers // len(pattern)`` stacked groups (fast compile at 95 layers, the
MaxText trick) with an optional un-scanned tail for non-divisible depths.
``scan_layers=False`` python-unrolls — required for PTQ calibration where
every layer needs its own activation-range site.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention import (
    AttentionConfig,
    attention,
    dense_attention,
    paged_attention,
)
from repro.core.gating import GateConfig, gate_probs, init_gate
from repro.core.softmax import ClippedSoftmaxConfig, softcap
from repro.nn.layers import (
    apply_rope,
    embedding_apply,
    embedding_attend,
    embedding_init,
    linear_apply,
    linear_init,
    norm_apply,
    norm_init,
    positional_embedding_apply,
    positional_embedding_init,
    rmsnorm_apply,
    rmsnorm_init,
    rope_angles,
)
from repro.nn.mlp import mlp_apply, mlp_init
from repro.nn.moe import MoEConfig, moe_apply, moe_init
from repro.nn.module import Array, Params, split_keys, tree_slice, tree_stack
from repro.nn.recurrent import (
    RGLRUConfig,
    griffin_block_apply,
    griffin_block_init,
    griffin_init_state,
)
from repro.nn.xlstm import (
    XLSTMConfig,
    mlstm_block_apply,
    mlstm_block_init,
    slstm_block_apply,
    slstm_block_init,
    xlstm_init_state,
)
from repro.quant.kv_cache import kv_quant
from repro.quant.qconfig import NO_QUANT, QuantContext


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None

    # block pattern (one "group"); kinds: attn | local_attn | griffin | mlstm | slstm
    pattern: Tuple[str, ...] = ("attn",)

    # attention
    causal: bool = True
    window: Optional[int] = None                # for local_attn kind
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    pos: str = "rope"                           # rope | learned | none
    rope_theta: float = 10000.0
    max_seq_len: int = 131072
    attn_chunk_size: int = 1024

    # norms / residual
    norm: str = "rmsnorm"                       # rmsnorm | layernorm
    norm_position: str = "pre"                  # pre | post (BERT)
    post_block_norm: bool = False               # gemma-2 sandwich norms

    # mlp
    mlp_kind: str = "swiglu"                    # gelu | gelu_tanh | swiglu | none
    moe: Optional[MoEConfig] = None

    # paper knobs
    softmax_cfg: ClippedSoftmaxConfig = ClippedSoftmaxConfig()
    gate_cfg: GateConfig = GateConfig(kind="none")

    # paged-KV read path: "auto" (Pallas kernel on TPU, XLA gather
    # elsewhere) | "kernel" | "gather" — see core.attention.paged_attention
    paged_backend: str = "auto"

    # embedding / io
    tie_embeddings: bool = True
    embed_scale: bool = False                   # gemma: * sqrt(d_model)
    input_kind: str = "tokens"                  # tokens | embeds | mixed
    frontend_dim: Optional[int] = None          # embeds input width (e.g. 512)
    n_prefix_embeds: int = 0                    # vlm: image-patch prefix length

    # sub-configs for non-attention mixers
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # vocab padded to a multiple of this so the vocab dim shards over the
    # 'model' mesh axis (padded logits are masked to -inf before the loss)
    vocab_pad_to: int = 1

    # execution
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"     # nothing | dots (save matmul outputs)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    init_std: float = 0.02

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return (self.vocab_size + m - 1) // m * m

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    def attn_cfg(self, kind: str) -> AttentionConfig:
        return AttentionConfig(
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.head_dim,
            causal=self.causal,
            window=self.window if kind == "local_attn" else None,
            logit_softcap=self.attn_logit_softcap,
            softmax=self.softmax_cfg,
            chunk_size=self.attn_chunk_size,
        )


# ==========================================================================
# Decode positions
# ==========================================================================
def _positions(pos, t: int) -> Array:
    """Absolute positions of a length-``t`` block: (T,) for a scalar ``pos``,
    (B, T) when ``pos`` is a per-row (B,) vector (slot-pool decode)."""
    p = jnp.asarray(pos, jnp.int32)
    return p[..., None] + jnp.arange(t, dtype=jnp.int32)


def _row_select(active: Array, new, old):
    """Keep ``new`` state only for rows where ``active`` is True. Used for
    decode states without a positional write index (recurrent h, conv tail)
    where a masked scatter does not apply."""
    def sel(n, o):
        if n is None:
            return n
        m = active.reshape(active.shape[0], *([1] * (n.ndim - 1)))
        return jnp.where(m, n, o.astype(n.dtype))
    return jax.tree_util.tree_map(sel, new, old)


def _token_mask(active: Optional[Array], b: int, t: int) -> Optional[Array]:
    """Normalize the ``active`` argument to a per-token (B, T) bool mask.

    ``active`` may be a per-row (B,) mask (every token of a row shares its
    fate — the decode-tick contract) or already per-token (B, T) — the
    chunked-prefill contract, where row b contributes ``counts[b] <= T``
    real tokens and the tail of its block is padding whose cache writes must
    be dropped."""
    if active is None:
        return None
    act = jnp.asarray(active)
    if act.ndim == 1:
        act = act[:, None]
    return jnp.broadcast_to(act.astype(jnp.bool_), (b, t))


def _row_active(active: Optional[Array]) -> Optional[Array]:
    """Per-row (B,) reduction of ``active`` for states without a positional
    write index (recurrent h/conv/cell). A row participates if ANY of its
    tokens is live; ragged (partially live) rows are not representable for
    recurrent states — the scheduler feeds recurrent models uniform-length
    steps (see ``serving.scheduler``)."""
    if active is None or active.ndim == 1:
        return active
    return active.any(axis=1)


# ==========================================================================
# Block init / apply
# ==========================================================================
def _attn_block_init(key: Array, cfg: ModelConfig, kind: str) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 8)
    std = cfg.init_std
    bias = cfg.norm == "layernorm"  # BERT/OPT-style models use biases
    p: Params = {
        "ln1": norm_init(cfg.norm, d, cfg.param_dtype),
        "q": linear_init(ks[0], d, hq * dh, bias=bias, std=std, dtype=cfg.param_dtype),
        "k": linear_init(ks[1], d, hkv * dh, bias=bias, std=std, dtype=cfg.param_dtype),
        "v": linear_init(ks[2], d, hkv * dh, bias=bias, std=std, dtype=cfg.param_dtype),
        "o": linear_init(ks[3], hq * dh, d, bias=bias, std=std, dtype=cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(dh, cfg.param_dtype)
        p["knorm"] = rmsnorm_init(dh, cfg.param_dtype)
    if cfg.gate_cfg.enabled:
        p["gate"] = init_gate(ks[4], cfg.gate_cfg, hq, dh, d, cfg.param_dtype)
    if cfg.mlp_kind != "none":
        p["ln2"] = norm_init(cfg.norm, d, cfg.param_dtype)
        if cfg.moe is not None:
            p["moe"] = moe_init(ks[5], d, cfg.moe, cfg.param_dtype)
        else:
            p["mlp"] = mlp_init(ks[5], d, cfg.d_ff, cfg.mlp_kind, cfg.param_dtype)
    if cfg.post_block_norm:
        p["post_ln1"] = norm_init(cfg.norm, d, cfg.param_dtype)
        if cfg.mlp_kind != "none":
            p["post_ln2"] = norm_init(cfg.norm, d, cfg.param_dtype)
    return p


def _block_init(key: Array, cfg: ModelConfig, kind: str) -> Params:
    if kind in ("attn", "local_attn"):
        return _attn_block_init(key, cfg, kind)
    if kind == "griffin":
        k1, k2, k3 = split_keys(key, 3)
        return {
            "ln1": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "griffin": griffin_block_init(k1, cfg.d_model, cfg.rglru, cfg.param_dtype),
            "ln2": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, cfg.param_dtype),
        }
    if kind == "mlstm":
        return {
            "ln": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "blk": mlstm_block_init(key, cfg.xlstm, cfg.param_dtype),
        }
    if kind == "slstm":
        return {
            "ln": norm_init(cfg.norm, cfg.d_model, cfg.param_dtype),
            "blk": slstm_block_init(key, cfg.xlstm, cfg.param_dtype),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def _attn_block_apply(
    p: Params, x: Array, cfg: ModelConfig, kind: str,
    rope: Optional[Tuple[Array, Array]],
    cache: Optional[dict], pos,
    ctx: QuantContext, name: str,
    active: Optional[Array] = None,
    paged_live_width: Optional[int] = None,
    paged_live_widths: Optional[Array] = None,
) -> Tuple[Array, Optional[dict], Array, dict]:
    """Returns (x_out, new_cache, attn_layer_output, moe_aux); the attention
    layer output is the tensor whose outliers the paper measures."""
    b, t, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    acfg = cfg.attn_cfg(kind)

    h = norm_apply(cfg.norm, p["ln1"], x, ctx, name + "/ln1") \
        if cfg.norm_position == "pre" else x
    q = linear_apply(p["q"], h, ctx, name + "/q").reshape(b, t, hq, dh)
    k = linear_apply(p["k"], h, ctx, name + "/k").reshape(b, t, hkv, dh)
    v = linear_apply(p["v"], h, ctx, name + "/v").reshape(b, t, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["qnorm"], q, ctx=ctx, name=name + "/qnorm")
        k = rmsnorm_apply(p["knorm"], k, ctx=ctx, name=name + "/knorm")
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    explicit_mask = None
    paged_table = None
    paged_scales = None
    if cache is not None:
        # align fresh q/k/v sharding with the d_head-sharded KV cache —
        # otherwise GSPMD falls back to "involuntary full rematerialization"
        # (replicate-then-reshard) on every decode step
        from repro.distributed.sharding import maybe_constrain
        q = maybe_constrain(q, "dp", None, None, "tp")
        k = maybe_constrain(k, "dp", None, None, "tp")
        v = maybe_constrain(v, "dp", None, None, "tp")
        cache_len = cache["k"].shape[1]
        is_ring = "pos_ids" in cache
        is_paged = "block_table" in cache
        per_row = jnp.ndim(pos) >= 1      # per-slot positions (decode engine)
        act_tok = _token_mask(active, b, t)   # (B, T) or None
        ring_read = None
        if is_paged:
            # Paged pool (num_blocks, block_size, Hkv, Dh): every write is
            # routed through block_table[row, pos // block_size] indirection.
            # Unallocated targets (table entry -1) and inactive tokens are
            # redirected out of bounds and dropped, the same masked-scatter
            # convention as the dense per-row path below.
            # Speculative decoding leans on a second property of this
            # scatter: a REJECTED draft token's write (an active token the
            # scheduler later declines to bank) is harmless, because reads
            # mask keys by logical position (> q is invisible) and the
            # row's next writes at those same (phys, slot) targets replace
            # the entry — with identical bits, since stored KV (incl. the
            # fused int8 quantize below) is a pure function of
            # (token value, logical position). Ring and recurrent caches
            # lack this replay property, so the scheduler refuses spec
            # there.
            nb, bs = cache["k"].shape[0], cache["k"].shape[1]
            table = cache["block_table"]                         # (B, W)
            tpos = jnp.broadcast_to(_positions(pos, t), (b, t))  # logical
            phys = jnp.take_along_axis(table, tpos // bs, axis=1,
                                       mode="fill", fill_value=-1)
            if act_tok is not None:
                phys = jnp.where(act_tok, phys, -1)
            phys = jnp.where(phys < 0, nb, phys)    # out of bounds -> dropped
            if "k_scale" in cache:
                # int8 pool: quantization fused into the same masked scatter.
                # Each token is quantized exactly ONCE from its fp value —
                # its int8 code + per-slot scale land together, so stored
                # bits are a pure function of (value, logical position) and
                # serving stays bitwise invariant to chunking/slots/resume
                # (see quant.kv_cache for why not a scalar per-block scale).
                kq, ks = kv_quant(k)
                vq, vs = kv_quant(v)
                k_cache = cache["k"].at[phys, tpos % bs].set(kq, mode="drop")
                v_cache = cache["v"].at[phys, tpos % bs].set(vq, mode="drop")
                new_cache = {
                    "k": k_cache, "v": v_cache,
                    "k_scale": cache["k_scale"].at[phys, tpos % bs].set(
                        ks, mode="drop"),
                    "v_scale": cache["v_scale"].at[phys, tpos % bs].set(
                        vs, mode="drop"),
                    "block_table": table,
                }
                paged_scales = (new_cache["k_scale"], new_cache["v_scale"])
            else:
                k_cache = cache["k"].at[phys, tpos % bs].set(
                    k.astype(cache["k"].dtype), mode="drop")
                v_cache = cache["v"].at[phys, tpos % bs].set(
                    v.astype(cache["v"].dtype), mode="drop")
                new_cache = {"k": k_cache, "v": v_cache, "block_table": table}
            paged_table = table
        elif per_row:
            # Masked per-token scatter: row b writes token j of its block at
            # position pos[b] + j; padding tokens (act_tok False) and dead
            # rows are redirected out of bounds and dropped — no write, no
            # double-buffer restore needed. A chunk (t > 1) must satisfy
            # t <= ring length for local_attn layers so its own writes do
            # not collide inside the ring (the scheduler caps chunks at the
            # window).
            tpos = _positions(pos, t)                                # (B, T)
            widx = tpos % cache_len if is_ring else tpos
            if act_tok is not None:
                widx = jnp.where(act_tok, widx, cache_len)
            bidx = jnp.arange(b)[:, None]
            k_cache = cache["k"].at[bidx, widx].set(
                k.astype(cache["k"].dtype), mode="drop")
            v_cache = cache["v"].at[bidx, widx].set(
                v.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": k_cache, "v": v_cache}
            if is_ring:
                pos_ids = cache["pos_ids"].at[bidx, widx].set(tpos, mode="drop")
                new_cache["pos_ids"] = pos_ids
                q_pos = tpos[:, :, None]                             # (B, T, 1)
                if t == 1:
                    # decode: the single fresh token never evicts in-window
                    # history, so attend over the updated ring directly
                    kp = pos_ids[:, None, :]                         # (B, 1, L)
                else:
                    # chunked prefill: a multi-token ring write can evict
                    # history that EARLIER queries of the same chunk still
                    # need (slot (pos+j) % L holds position pos+j-L, inside
                    # the window of queries j' < j). Read the PRE-write ring
                    # plus the fresh chunk as separate KV entries instead:
                    # the position-id mask picks exactly the in-window,
                    # causal, live subset of both segments, and padding
                    # tokens of the fresh segment are tagged -1.
                    fpos = tpos if act_tok is None else \
                        jnp.where(act_tok, tpos, -1)
                    kp = jnp.concatenate([cache["pos_ids"], fpos],
                                         axis=1)[:, None, :]   # (B, 1, L+T)
                    ring_read = (
                        jnp.concatenate(
                            [cache["k"], k.astype(cache["k"].dtype)], axis=1),
                        jnp.concatenate(
                            [cache["v"], v.astype(cache["v"].dtype)], axis=1),
                    )
                    # the concat KV axis (L + T) varies with chunk size, but
                    # alpha-resolved clipping must be invariant to how the
                    # prompt is chunked: pin gamma to the ring length — the
                    # axis every other ring path (decode t==1, one-shot
                    # scalar prefill) resolves it from
                    if not acfg.softmax.is_vanilla:
                        acfg = dataclasses.replace(
                            acfg, softmax=ClippedSoftmaxConfig(
                                gamma=acfg.softmax.resolve_gamma(cache_len),
                                zeta=acfg.softmax.zeta))
                explicit_mask = (kp >= 0) & (kp <= q_pos) & \
                    (kp > q_pos - cfg.window)
                acfg = dataclasses.replace(acfg, causal=False, window=None)
        elif is_ring:
            # ring buffer holding the last `window` tokens (decode, t == 1)
            slot = pos % cache_len
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            pos_ids = jax.lax.dynamic_update_slice_in_dim(
                cache["pos_ids"],
                jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32) + pos, (b, t)),
                slot, axis=1)
            new_cache = {"k": k_cache, "v": v_cache, "pos_ids": pos_ids}
            q_pos = (pos + jnp.arange(t))[None, :, None]             # (1, T, 1)
            kp = pos_ids[:, None, :]                                 # (B, 1, L)
            explicit_mask = (kp >= 0) & (kp <= q_pos) & (kp > q_pos - cfg.window)
            acfg = dataclasses.replace(acfg, causal=False, window=None)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            new_cache = {"k": k_cache, "v": v_cache}
        k_all, v_all = ring_read if ring_read is not None else (k_cache, v_cache)
        q_offset = pos
    else:
        new_cache = None
        k_all, v_all = k, v
        q_offset = 0

    gate_pi = None
    if cfg.gate_cfg.enabled:
        # per-head view of the attention input (paper Sec 4.2); when
        # n_heads*d_head != d_model (gemma2) the per-head query projection
        # is the per-head view instead.
        if hq * dh == d:
            x_heads = h.reshape(b, t, hq, dh)
        else:
            x_heads = q
        gate_pi = gate_probs(p["gate"], cfg.gate_cfg, x_heads, h)

    if paged_table is not None:
        k_scale, v_scale = paged_scales if paged_scales is not None else (None, None)
        attn_out = paged_attention(q, k_all, v_all, paged_table, acfg,
                                   q_offset=q_offset, gate_pi=gate_pi,
                                   live_width=paged_live_width,
                                   live_widths=paged_live_widths,
                                   k_scale=k_scale, v_scale=v_scale,
                                   backend=cfg.paged_backend)
    elif explicit_mask is not None:
        attn_out = dense_attention(q, k_all, v_all, acfg, mask=explicit_mask,
                                   q_offset=q_offset, gate_pi=gate_pi)
    else:
        attn_out = attention(q, k_all, v_all, acfg, q_offset=q_offset, gate_pi=gate_pi)
    attn_out = ctx.act(name + "/attn.out", attn_out.reshape(b, t, hq * dh))
    y = linear_apply(p["o"], attn_out, ctx, name + "/o")
    if cfg.post_block_norm:
        y = norm_apply(cfg.norm, p["post_ln1"], y, ctx, name + "/post_ln1")
    x = x + y
    if cfg.norm_position == "post":
        x = norm_apply(cfg.norm, p["ln1"], x, ctx, name + "/ln1")
    attn_layer_out = x  # residual-stream value after attention (paper metric)

    moe_aux = {"load_balance": jnp.zeros((), jnp.float32),
               "router_z": jnp.zeros((), jnp.float32)}
    if cfg.mlp_kind != "none":
        h2 = norm_apply(cfg.norm, p["ln2"], x, ctx, name + "/ln2") \
            if cfg.norm_position == "pre" else x
        if cfg.moe is not None:
            # inactive decode rows must not claim expert capacity: their
            # tokens would displace live rows' tokens in the dropping
            # dispatch (slot-major priority), silently changing live outputs
            y2, moe_aux = moe_apply(p["moe"], h2, cfg.moe, ctx, name + "/moe",
                                    active=active)
        else:
            y2 = mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx, name + "/mlp")
        if cfg.post_block_norm:
            y2 = norm_apply(cfg.norm, p["post_ln2"], y2, ctx, name + "/post_ln2")
        x = x + y2
        if cfg.norm_position == "post":
            x = norm_apply(cfg.norm, p["ln2"], x, ctx, name + "/ln2")
    return x, new_cache, attn_layer_out, moe_aux


def _zero_aux():
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)}


def _block_apply(
    p: Params, x: Array, cfg: ModelConfig, kind: str,
    rope, cache, pos, ctx: QuantContext, name: str,
    active: Optional[Array] = None,
    paged_live_width: Optional[int] = None,
    paged_live_widths: Optional[Array] = None,
) -> Tuple[Array, Optional[dict], Array, dict]:
    if kind in ("attn", "local_attn"):
        return _attn_block_apply(p, x, cfg, kind, rope, cache, pos, ctx, name,
                                 active=active,
                                 paged_live_width=paged_live_width,
                                 paged_live_widths=paged_live_widths)
    if kind == "griffin":
        h = norm_apply(cfg.norm, p["ln1"], x, ctx, name + "/ln1")
        y, new_state = griffin_block_apply(p["griffin"], h, cfg.rglru, cache, ctx, name + "/griffin")
        if active is not None and cache is not None:
            new_state = _row_select(_row_active(active), new_state, cache)
        x = x + y
        mix_out = x
        h2 = norm_apply(cfg.norm, p["ln2"], x, ctx, name + "/ln2")
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp_kind, ctx, name + "/mlp")
        return x, new_state, mix_out, _zero_aux()
    if kind in ("mlstm", "slstm"):
        h = norm_apply(cfg.norm, p["ln"], x, ctx, name + "/ln")
        fn = mlstm_block_apply if kind == "mlstm" else slstm_block_apply
        y, new_state = fn(p["blk"], h, cfg.xlstm, cache, ctx, name + f"/{kind}")
        if active is not None and cache is not None:
            new_state = _row_select(_row_active(active), new_state, cache)
        x = x + y
        return x, new_state, x, _zero_aux()
    raise ValueError(kind)


# ==========================================================================
# Whole model
# ==========================================================================
def model_init(key: Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, cfg.n_layers + 4)
    p: Params = {}
    if cfg.input_kind in ("tokens", "mixed"):
        p["embed"] = embedding_init(keys[-1], cfg.padded_vocab, cfg.d_model,
                                    cfg.init_std, cfg.param_dtype)
    if cfg.input_kind in ("embeds", "mixed") and cfg.frontend_dim is not None:
        p["frontend_proj"] = linear_init(keys[-2], cfg.frontend_dim, cfg.d_model,
                                         dtype=cfg.param_dtype)
    if cfg.pos == "learned":
        p["pos_embed"] = positional_embedding_init(keys[-3], cfg.max_seq_len,
                                                   cfg.d_model, cfg.param_dtype)
    # layer groups
    glen = len(cfg.pattern)
    groups: List[Params] = []
    for g in range(cfg.n_groups):
        blocks = {}
        for i, kind in enumerate(cfg.pattern):
            blocks[f"b{i}"] = _block_init(keys[g * glen + i], cfg, kind)
        groups.append(blocks)
    if cfg.scan_layers and cfg.n_groups > 0:
        p["groups"] = tree_stack(groups)
    else:
        p["layers"] = groups
    # tail (non-divisible depths, e.g. recurrentgemma 38 = 12*3 + 2)
    tail = {}
    for i, kind in enumerate(cfg.tail_pattern):
        tail[f"t{i}"] = _block_init(keys[cfg.n_groups * glen + i], cfg, kind)
    if tail:
        p["tail"] = tail
    p["final_norm"] = norm_init(cfg.norm, cfg.d_model, cfg.param_dtype)
    if not cfg.tie_embeddings or cfg.input_kind == "embeds":
        p["lm_head"] = linear_init(keys[-4], cfg.d_model, cfg.padded_vocab,
                                   bias=False, std=cfg.init_std, dtype=cfg.param_dtype)
    return p


def _cache_entry(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype) -> Params:
    """Dense decode state of one block: KV tensors for attention blocks
    (ring buffer for local_attn), recurrent states otherwise."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if kind in ("attn", "local_attn"):
        # local attention only ever needs `window` history (ring buffer)
        length = min(max_len, cfg.window) if (kind == "local_attn" and cfg.window) else max_len
        c = {
            "k": jnp.zeros((batch, length, hkv, dh), dtype),
            "v": jnp.zeros((batch, length, hkv, dh), dtype),
        }
        if kind == "local_attn" and cfg.window and length < cfg.max_seq_len:
            # per-row ring positions: slots decode at different offsets
            c["pos_ids"] = jnp.full((batch, length), -1, jnp.int32)
        return c
    if kind == "griffin":
        return griffin_init_state(batch, cfg.rglru, dtype)
    return xlstm_init_state(batch, kind, cfg.xlstm, dtype)


def _assemble_cache(cfg: ModelConfig, one) -> Params:
    """Mirror the param grouping (scan stacking + unrolled tail) so the layer
    scan can zip params with cache."""
    groups = [
        {f"b{i}": one(kind) for i, kind in enumerate(cfg.pattern)}
        for _ in range(cfg.n_groups)
    ]
    cache: Params = {}
    if cfg.scan_layers and cfg.n_groups > 0:
        cache["groups"] = tree_stack(groups)
    else:
        cache["layers"] = groups
    if cfg.tail_pattern:
        cache["tail"] = {f"t{i}": one(kind) for i, kind in enumerate(cfg.tail_pattern)}
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Params:
    """Dense per-layer decode state: every batch row reserves ``max_len`` KV
    positions up front. Simple and fully static, but pool memory scales with
    the worst-case length; ``init_paged_cache`` is the live-token-scaled
    alternative."""
    dtype = dtype or cfg.compute_dtype
    return _assemble_cache(cfg, partial(_cache_entry, cfg, batch=batch,
                                        max_len=max_len, dtype=dtype))


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     num_blocks: int, block_size: int = 16,
                     dtype=None, kv_int8: bool = False) -> Params:
    """Paged decode state (vLLM-style): each global-attention layer holds a
    shared block pool ``k``/``v`` of shape (num_blocks, block_size, Hkv, Dh)
    plus a per-row ``block_table`` (batch, ceil(max_len / block_size)) of
    physical block ids (-1 = unallocated). Cache memory scales with *live
    tokens* (num_blocks * block_size across the whole batch) instead of
    batch * max_len, and ``max_len`` becomes a per-row logical cap only.

    Block tables are owned by the scheduler (host side): allocation and
    freeing happen outside jit, the tables are passed in as cache leaves, and
    the model only reads them — cache writes go through
    ``block_table[pos // block_size]`` indirection (see _attn_block_apply).
    Ring (local_attn) and recurrent states keep their dense per-row layout;
    they are already O(window) / O(1) per row.

    ``kv_int8=True`` stores the pools as int8 plus per-block scale vectors
    ``k_scale``/``v_scale`` of shape (num_blocks, block_size) — one f32
    scale per token slot, written by the same masked scatter that writes
    the pool (see quant.kv_cache). KV block memory drops ~3.5x for typical
    head shapes (``paged_kv_block_bytes``), so an equal-byte pool holds
    proportionally more blocks and admits proportionally more concurrent
    rows. Only the "attn" pools quantize; ring/recurrent state stays fp.
    """
    dtype = dtype or cfg.compute_dtype
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if max_len % block_size:
        raise ValueError(
            f"max_len={max_len} must be a multiple of block_size="
            f"{block_size}: the virtual KV length (table width * "
            f"block_size) must equal the logical cap so paged and dense "
            f"attention see the same KV axis length — softmax_cfg.alpha "
            f"resolves gamma = -alpha/T from it, so a padded axis would "
            f"silently change the clip threshold")
    n_entries = max_len // block_size

    def one(kind: str):
        if kind == "attn":
            pool_dtype = jnp.int8 if kv_int8 else dtype
            c = {
                "k": jnp.zeros((num_blocks, block_size, hkv, dh), pool_dtype),
                "v": jnp.zeros((num_blocks, block_size, hkv, dh), pool_dtype),
                "block_table": jnp.full((batch, n_entries), -1, jnp.int32),
            }
            if kv_int8:
                c["k_scale"] = jnp.zeros((num_blocks, block_size), jnp.float32)
                c["v_scale"] = jnp.zeros((num_blocks, block_size), jnp.float32)
            return c
        return _cache_entry(cfg, kind, batch, max_len, dtype)

    return _assemble_cache(cfg, one)


def paged_kv_block_bytes(cfg: ModelConfig, block_size: int = 16,
                         kv_int8: bool = False, dtype=None) -> int:
    """Bytes ONE pool block costs per global-attention layer (k + v +, for
    int8, the two per-slot scale vectors). The capacity tests and the
    serving benchmark size fp and int8 pools to equal byte budgets with
    this, so 'admits Nx more rows at equal memory' is computed from the
    same accounting the pools actually allocate."""
    dtype = dtype or cfg.compute_dtype
    elems = block_size * cfg.n_kv_heads * cfg.head_dim
    if kv_int8:
        return 2 * elems * 1 + 2 * block_size * 4     # int8 kv + f32 scales
    return 2 * elems * jnp.dtype(dtype).itemsize


def copy_pool_blocks(cache: Params, src: Array, dst: Array) -> Params:
    """Copy physical pool blocks ``src[i] -> dst[i]`` in every paged pool
    of ``cache`` — K/V blocks and, for int8 KV, their per-slot scale
    vectors travel together (a block's scales are meaningless without it).

    This is the device half of the scheduler's copy-on-write: when a row
    must write into a block that other owners (the prefix trie, a
    sampling-group sibling) still reference, the host remaps the row's
    table entry to a fresh block and this helper materializes the content
    copy BEFORE the tick's forward lands any write. Each leaf is one
    fused gather-then-scatter (``leaf.at[dst].set(leaf[src])`` reads all
    sources from the pre-copy pool), so a pair whose source block was
    released and immediately re-allocated as another pair's destination
    still copies pre-copy content. Block tables and batch-led leaves
    (ring/recurrent state, dense KV) pass through untouched."""
    def copy_entry(entry):
        stacked = entry["block_table"].ndim == 3        # scanned: (G, B, W)
        out = dict(entry)
        for name in ("k", "v", "k_scale", "v_scale"):
            leaf = entry.get(name)
            if leaf is None:
                continue
            if stacked:
                out[name] = leaf.at[:, dst].set(leaf[:, src])
            else:
                out[name] = leaf.at[dst].set(leaf[src])
        return out

    def walk(node):
        if isinstance(node, dict):
            if "block_table" in node:
                return copy_entry(node)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(cache)


def _embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
                  pos, ctx: QuantContext) -> Array:
    scale = math.sqrt(cfg.d_model) if cfg.embed_scale else None
    parts = []
    if cfg.input_kind in ("embeds", "mixed") and "embeds" in batch:
        e = batch["embeds"].astype(cfg.compute_dtype)
        if "frontend_proj" in params:
            e = linear_apply(params["frontend_proj"], e, ctx, "frontend_proj")
        parts.append(e)
    if cfg.input_kind in ("tokens", "mixed") and "tokens" in batch:
        parts.append(
            embedding_apply(params["embed"], batch["tokens"], ctx, "embed", scale
                            ).astype(cfg.compute_dtype)
        )
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.pos == "learned":
        t = x.shape[1]
        positions = _positions(pos, t)        # (T,) or per-row (B, T)
        x = x + positional_embedding_apply(params["pos_embed"], positions).astype(x.dtype)
    return x


def model_apply(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, Array],
    ctx: QuantContext = NO_QUANT,
    cache: Optional[Params] = None,
    pos: Any = 0,
    active: Optional[Array] = None,
    collect_acts: bool = False,
    paged_live_width: Optional[int] = None,
    paged_live_widths: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Any]]:
    """Forward pass.

    batch: {"tokens": (B,T) int32} and/or {"embeds": (B,T,F)}.
    cache/pos: decode state; pass T=1 (or prefill chunk) with a cache.
    ``pos`` may be a shared scalar or a per-row (B,) vector (slot-pool
    decode); with a vector, cache writes scatter per row. ``active`` is an
    optional bool mask — per-row (B,) or per-token (B, T): masked entries
    still compute (their logits are garbage) but their cache/state writes
    are dropped — the masked-write contract the continuous batcher relies
    on. A per-token mask is what lets one fused step mix decode rows
    (1 live token) with prefill chunks (``counts[b]`` live tokens) of
    unequal lengths: row b's padding tail is simply inactive. Recurrent
    blocks (griffin/xlstm) reduce the mask per row (``any`` over tokens),
    so ragged rows are only supported for attention-family caches — the
    scheduler feeds recurrent models uniform-length steps.
    The cache may be dense (``init_cache``: per-row contiguous KV) or paged
    (``init_paged_cache``: global block pools + per-row block tables, writes
    routed through ``block_table[pos // block_size]``); the layout is
    detected per layer from the cache leaves, and both produce bitwise
    identical logits for the same tokens. ``paged_live_width`` (static int)
    optionally bounds the paged READ path to the first N block-table
    entries — allocation is prefix-dense, so the scheduler passes the
    bucketed max blocks-in-use per tick and the attention cost tracks live
    tokens instead of the table width (see ``paged_attention``).
    ``paged_live_widths`` ((B,) int32, optional) additionally masks each
    row's paged READ at its own block count rather than the tick max.
    Returns (logits (B,T,vocab) f32, aux) where aux may contain
    "attn_outputs" (stacked per-layer residual values) and "cache".
    """
    x = _embed_inputs(params, cfg, batch, pos, ctx)
    b, t, _ = x.shape

    rope = None
    if cfg.pos == "rope":
        positions = _positions(pos, t)        # (T,) or per-row (B, T)
        rope = rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    aux: Dict[str, Any] = {}
    acts: List[Array] = []

    def group_apply(x, gparams, gcache):
        new_gcache = {}
        gacts = []
        gaux = _zero_aux()
        for i, kind in enumerate(cfg.pattern):
            c = None if gcache is None else gcache[f"b{i}"]
            x, nc, a, ba = _block_apply(gparams[f"b{i}"], x, cfg, kind, rope, c, pos,
                                        ctx, f"layer_{kind}{i}", active=active,
                                        paged_live_width=paged_live_width,
                                        paged_live_widths=paged_live_widths)
            new_gcache[f"b{i}"] = nc
            gacts.append(a)
            gaux = {k: gaux[k] + ba[k] for k in gaux}
        return x, new_gcache, gacts, gaux

    new_cache: Optional[Params] = None
    if cfg.scan_layers and cfg.n_groups > 0:
        gfn = group_apply
        if cfg.remat and cache is None:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            gfn = jax.checkpoint(group_apply, policy=policy)

        if cache is None:
            def scan_body_nc(x, gparams):
                x, _, gacts, gaux = gfn(x, gparams, None)
                return x, (jnp.stack([jnp.max(jnp.abs(a)) for a in gacts]), gaux)

            x, (act_stats, gauxs) = jax.lax.scan(scan_body_nc, x, params["groups"])
        else:
            def scan_body(x, inp):
                gparams, gcache = inp
                x, new_gcache, gacts, gaux = gfn(x, gparams, gcache)
                return x, (new_gcache,
                           jnp.stack([jnp.max(jnp.abs(a)) for a in gacts]), gaux)

            x, (new_caches, act_stats, gauxs) = jax.lax.scan(
                scan_body, x, (params["groups"], cache["groups"]))
            new_cache = {"groups": new_caches}
        aux["act_stats"] = act_stats
        aux["moe_aux"] = {k: jnp.sum(v) for k, v in gauxs.items()}
    else:
        new_cache = {"layers": []} if cache is not None else None
        moe_tot = _zero_aux()
        for g in range(cfg.n_groups):
            gparams = params["layers"][g] if "layers" in params else tree_slice(params["groups"], g)
            gcache = cache["layers"][g] if cache is not None else None
            x, ngc, gacts, gaux = group_apply(x, gparams, gcache)
            moe_tot = {k: moe_tot[k] + gaux[k] for k in moe_tot}
            if cache is not None:
                new_cache["layers"].append(ngc)
            acts.extend(gacts)
        aux["moe_aux"] = moe_tot

    # tail blocks (always unrolled)
    if cfg.tail_pattern:
        tcache_new = {}
        for i, kind in enumerate(cfg.tail_pattern):
            c = None if cache is None else cache["tail"][f"t{i}"]
            x, nc, a, ta = _block_apply(params["tail"][f"t{i}"], x, cfg, kind, rope, c,
                                        pos, ctx, f"tail_{kind}{i}", active=active,
                                        paged_live_width=paged_live_width,
                                        paged_live_widths=paged_live_widths)
            aux["moe_aux"] = {k: aux.get("moe_aux", _zero_aux())[k] + ta[k]
                              for k in ta}
            tcache_new[f"t{i}"] = nc
            acts.append(a)
        if cache is not None:
            new_cache["tail"] = tcache_new

    if acts and collect_acts:
        aux["attn_outputs"] = acts
    if cache is not None:
        aux["cache"] = new_cache

    x = norm_apply(cfg.norm, params["final_norm"], x, ctx, "final_norm")
    if "lm_head" in params:
        logits = linear_apply(params["lm_head"], x, ctx, "lm_head").astype(jnp.float32)
    else:
        logits = embedding_attend(params["embed"], x, ctx, "lm_head")
    logits = softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits, aux
