from repro.nn.module import (
    DTypePolicy,
    cast_tree,
    flatten_params,
    param_bytes,
    param_count,
    split_keys,
    tree_slice,
    tree_stack,
)

__all__ = [
    "DTypePolicy", "cast_tree", "flatten_params", "param_bytes",
    "param_count", "split_keys", "tree_slice", "tree_stack",
]
