"""Basic layers: linear, norms, embeddings, rotary position embeddings.

Every layer takes an optional ``QuantContext`` + site name so the PTQ driver
can fake-quantize weights and activations exactly where integer hardware
would (inputs and outputs of every matmul — paper Section 5).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Array, DTypePolicy, Params, normal_init
from repro.quant.qconfig import NO_QUANT, QuantContext


# --------------------------------------------------------------------------
# Linear
# --------------------------------------------------------------------------
def linear_init(
    key: Array, d_in: int, d_out: int, *, bias: bool = True,
    std: Optional[float] = None, dtype=jnp.float32,
) -> Params:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": normal_init(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(
    p: Params, x: Array, ctx: QuantContext = NO_QUANT, name: str = "linear",
    compute_dtype=None,
) -> Array:
    if ctx.mode == "int8" and "w_q8" in p:
        return _linear_int8_apply(p, x, ctx, name)
    w = ctx.weight(name, p["w"])
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        w = w.astype(compute_dtype)
    x = ctx.act(name + ".in", x)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return ctx.act(name + ".out", y)


def _linear_int8_apply(p: Params, x: Array, ctx: QuantContext,
                       name: str) -> Array:
    """Hardware W8A8 path: int8 x int8 -> int32 through the MXU kernel.

    Weights come pre-quantized on the params tree
    (quant.int8_weights.attach_int8_weights); the activation range is the
    STATIC per-tensor (s, z) calibrated for this site — falling back to
    dynamic in-kernel ranging only if the site was never seen."""
    from repro.kernels.int8_matmul import int8_matmul  # avoid import cycle

    qp = ctx.act_qparams(name + ".in")
    s_x, z_x = qp if qp is not None else (None, None)
    lead = x.shape[:-1]
    y = int8_matmul(
        x.reshape(-1, x.shape[-1]), p["w_q8"], p["w_scale"],
        x_scale=s_x, x_zero=z_x,
        interpret=jax.default_backend() != "tpu")
    y = y.reshape(*lead, p["w_q8"].shape[-1])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# Norms — f32 accumulation regardless of compute dtype
# --------------------------------------------------------------------------
def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Params, x: Array, eps: float = 1e-6,
                    ctx: QuantContext = NO_QUANT, name: str = "ln") -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return ctx.act(name + ".out", y.astype(dt))


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: Array, eps: float = 1e-6,
                  ctx: QuantContext = NO_QUANT, name: str = "rms",
                  zero_centered: bool = False) -> Array:
    """RMSNorm; ``zero_centered=True`` uses the gemma convention
    (scale stored as gamma-1 around zero)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:
        scale = scale + 1.0
    return ctx.act(name + ".out", (y * scale).astype(dt))


def norm_init(kind: str, d: int, dtype=jnp.float32) -> Params:
    return layernorm_init(d, dtype) if kind == "layernorm" else rmsnorm_init(d, dtype)


def norm_apply(kind: str, p: Params, x: Array, ctx: QuantContext = NO_QUANT,
               name: str = "norm", zero_centered: bool = False) -> Array:
    if kind == "layernorm":
        return layernorm_apply(p, x, ctx=ctx, name=name)
    return rmsnorm_apply(p, x, ctx=ctx, name=name, zero_centered=zero_centered)


# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------
def embedding_init(key: Array, vocab: int, d: int, std: float = 0.02,
                   dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (vocab, d), std, dtype)}


def embedding_apply(p: Params, ids: Array, ctx: QuantContext = NO_QUANT,
                    name: str = "embed", scale: Optional[float] = None) -> Array:
    table = ctx.weight(name, p["table"])
    y = jnp.take(table, ids, axis=0)
    if scale is not None:
        y = y * jnp.asarray(scale, y.dtype)
    return ctx.act(name + ".out", y)


def embedding_attend(p: Params, x: Array, ctx: QuantContext = NO_QUANT,
                     name: str = "lm_head") -> Array:
    """Tied-softmax output head: logits = x @ table^T."""
    table = ctx.weight(name, p["table"])
    x = ctx.act(name + ".in", x)
    return x.astype(jnp.float32) @ table.T.astype(jnp.float32)


def positional_embedding_init(key: Array, max_len: int, d: int,
                              dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (max_len, d), 0.02, dtype)}


def positional_embedding_apply(p: Params, positions: Array) -> Array:
    return jnp.take(p["table"], positions, axis=0)


# --------------------------------------------------------------------------
# Rotary position embeddings (RoPE)
# --------------------------------------------------------------------------
def rope_angles(positions: Array, d_head: int, theta: float = 10000.0
                ) -> Tuple[Array, Array]:
    """cos/sin tables, shape (..., T, d_head/2), f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, T, H, D); cos/sin: (T, D/2) or (B, T, D/2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:     # (T, D/2) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                 # (B, T, D/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(dt)


# --------------------------------------------------------------------------
# Depthwise causal temporal conv (griffin / audio frontends)
# --------------------------------------------------------------------------
def conv1d_init(key: Array, d: int, width: int, dtype=jnp.float32) -> Params:
    return {
        "w": normal_init(key, (width, d), 1.0 / math.sqrt(width), dtype),
        "b": jnp.zeros((d,), dtype),
    }


def conv1d_apply(p: Params, x: Array, state: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """Causal depthwise conv over time. x: (B, T, D).

    ``state``: (B, width-1, D) history for decode; returns (y, new_state).
    """
    w = p["w"]
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    y = y + p["b"].astype(x.dtype)
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y, new_state
