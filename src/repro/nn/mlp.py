"""Feed-forward blocks: classic GELU MLP (BERT/OPT/ViT/hubert) and
SwiGLU (llama/qwen/gemma/deepseek family)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import linear_apply, linear_init
from repro.nn.module import Array, Params, split_keys
from repro.quant.qconfig import NO_QUANT, QuantContext


def mlp_init(key: Array, d_model: int, d_ff: int, kind: str = "gelu",
             dtype=jnp.float32) -> Params:
    if kind in ("gelu", "gelu_tanh", "relu"):
        k1, k2 = split_keys(key, 2)
        return {
            "up": linear_init(k1, d_model, d_ff, dtype=dtype),
            "down": linear_init(k2, d_ff, d_model, dtype=dtype),
        }
    if kind in ("swiglu", "geglu"):
        k1, k2, k3 = split_keys(key, 3)
        return {
            "gate": linear_init(k1, d_model, d_ff, bias=False, dtype=dtype),
            "up": linear_init(k2, d_model, d_ff, bias=False, dtype=dtype),
            "down": linear_init(k3, d_ff, d_model, bias=False, dtype=dtype),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def _act(kind: str, x: Array) -> Array:
    if kind in ("gelu", "geglu"):
        return jax.nn.gelu(x, approximate=False)
    if kind == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "swiglu":
        return jax.nn.silu(x)
    raise ValueError(kind)


def mlp_apply(p: Params, x: Array, kind: str, ctx: QuantContext = NO_QUANT,
              name: str = "mlp") -> Array:
    if kind in ("gelu", "gelu_tanh", "relu"):
        h = _act(kind, linear_apply(p["up"], x, ctx, name + "/up"))
        h = ctx.act(name + "/act.out", h)
        return linear_apply(p["down"], h, ctx, name + "/down")
    # gated variants
    g = _act(kind, linear_apply(p["gate"], x, ctx, name + "/gate"))
    u = linear_apply(p["up"], x, ctx, name + "/up")
    h = ctx.act(name + "/act.out", g * u)
    return linear_apply(p["down"], h, ctx, name + "/down")
