"""Minimal functional module system.

No flax/optax in this environment, so layers are plain functions:

    init(key, ...) -> params (nested dict pytree)
    apply(params, x, ...) -> y

Param trees are nested dicts keyed by strings; ``flatten_params`` produces
'/'-joined paths that feed the regex sharding-rule engine in
``repro.distributed.sharding`` (the same role flax param names play in
MaxText).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any  # nested dict pytree


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy: params stored in ``param_dtype``, math in
    ``compute_dtype`` (bf16 on TPU), softmax/norm accumulation in f32."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @staticmethod
    def bf16() -> "DTypePolicy":
        return DTypePolicy(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)

    @staticmethod
    def bf16_params_f32() -> "DTypePolicy":
        # bf16 weights, f32 master math — used for small CPU smoke runs
        return DTypePolicy(param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


F32 = DTypePolicy()


def normal_init(key: Array, shape: Tuple[int, ...], std: float, dtype) -> Array:
    return (std * jax.random.normal(key, shape)).astype(dtype)


def truncated_normal_init(key: Array, shape, std: float, dtype) -> Array:
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def fan_in_init(key: Array, shape, dtype) -> Array:
    """LeCun-normal on the second-to-last axis product (matmul fan-in)."""
    fan_in = shape[0] if len(shape) == 2 else int(jnp.prod(jnp.array(shape[:-1])))
    return normal_init(key, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


def split_keys(key: Array, n: int) -> List[Array]:
    return list(jax.random.split(key, n))


def flatten_params(params: Params, prefix: str = "") -> Iterator[Tuple[str, Array]]:
    """Yield ('/'-joined path, leaf) pairs in deterministic order."""
    if isinstance(params, dict):
        for k in sorted(params.keys()):
            yield from flatten_params(params[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            yield from flatten_params(v, f"{prefix}/{i}" if prefix else str(i))
    elif params is None:
        return
    else:
        yield prefix, params


def param_count(params: Params) -> int:
    return sum(int(p.size) for _, p in flatten_params(params))


def param_bytes(params: Params) -> int:
    return sum(int(p.size) * p.dtype.itemsize for _, p in flatten_params(params))


def tree_stack(trees: List[Params]) -> Params:
    """Stack a list of identical pytrees along a new leading axis — used to
    build scanned layer groups."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_slice(tree: Params, i) -> Params:
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def cast_tree(tree: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
