"""Mixture-of-Experts feed-forward (granite-moe, qwen2-moe).

Router: linear -> softmax -> top-k, probabilities renormalized over the
selected experts. Optional shared experts (qwen2-moe: 4 shared + 60 routed)
are always-on SwiGLU branches added to the routed output.

Two execution paths:

  * ``dense``    — every expert computes every token, combined with the
    (sparse) routing weights. Exact, simple, O(E/k) FLOPs overhead — the
    oracle for tests and the small-smoke path.
  * ``dispatch`` — GShard-style capacity-based dispatch: tokens are grouped
    (``group_size``), each group builds a (G, E, C) one-hot dispatch tensor,
    experts run on their (C)-token buffers, and a combine einsum scatters
    results back. Tokens over capacity are dropped (residual passes them
    through untouched — exactly the no-update the paper studies). This is
    the path the dry-run lowers at scale; experts shard over the "model"
    mesh axis (EP).

Aux losses: load-balancing loss (Switch-style, mean over groups of
E * dot(frac_tokens, frac_prob)) and router z-loss, both returned for the
train step to weight.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import linear_init
from repro.nn.module import Array, Params, split_keys
from repro.quant.qconfig import NO_QUANT, QuantContext


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    n_shared_experts: int = 0      # qwen2-moe shared experts
    shared_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    group_size: int = 4096         # tokens per dispatch group
    mlp_kind: str = "swiglu"
    exec_mode: str = "dispatch"    # "dense" | "dispatch"

    @property
    def shared_ff(self) -> int:
        return self.shared_d_ff if self.shared_d_ff is not None else self.d_ff * self.n_shared_experts


def moe_init(key: Array, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    kr, ke, ks = split_keys(key, 3)
    std = 1.0 / (d_model ** 0.5)
    e, f = cfg.n_experts, cfg.d_ff
    k1, k2, k3 = split_keys(ke, 3)
    p: Params = {
        "router": linear_init(kr, d_model, e, bias=False, dtype=jnp.float32),
        # stacked expert weights: (E, d_model, d_ff) / (E, d_ff, d_model)
        "w_gate": (std * jax.random.normal(k1, (e, d_model, f))).astype(dtype),
        "w_up": (std * jax.random.normal(k2, (e, d_model, f))).astype(dtype),
        "w_down": ((1.0 / f ** 0.5) * jax.random.normal(k3, (e, f, d_model))).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        from repro.nn.mlp import mlp_init
        p["shared"] = mlp_init(ks, d_model, cfg.shared_ff, cfg.mlp_kind, dtype)
    return p


def _router(p: Params, x2d: Array, cfg: MoEConfig, ctx: QuantContext, name: str
            ) -> Tuple[Array, Array, Dict[str, Array]]:
    """Returns (top-k probs (N,k), top-k idx (N,k), aux losses)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # Switch load-balance loss + z-loss
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts), axis=1), axis=0
    )                                                              # (E,)
    aux = {
        "load_balance": cfg.n_experts * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return top_p, top_i, aux


def _expert_ffn(p: Params, xb: Array, cfg: MoEConfig) -> Array:
    """Apply every expert to its buffer. xb: (E, C, d_model)."""
    g = jnp.einsum("ecd,edf->ecf", xb, p["w_gate"].astype(xb.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, p["w_up"].astype(xb.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xb.dtype))


def _moe_dense(p: Params, x2d: Array, top_p, top_i, cfg: MoEConfig) -> Array:
    """Reference: all experts on all tokens, sparse combine."""
    g = jnp.einsum("nd,edf->nef", x2d, p["w_gate"].astype(x2d.dtype))
    u = jnp.einsum("nd,edf->nef", x2d, p["w_up"].astype(x2d.dtype))
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("nef,efd->ned", h, p["w_down"].astype(x2d.dtype))  # (N,E,D)
    combine = jnp.zeros((x2d.shape[0], cfg.n_experts), x2d.dtype)
    combine = jnp.sum(
        jax.nn.one_hot(top_i, cfg.n_experts, dtype=x2d.dtype) * top_p[..., None].astype(x2d.dtype),
        axis=1,
    )
    return jnp.einsum("ned,ne->nd", y_all, combine)


def _moe_dispatch(p: Params, x2d: Array, top_p, top_i, cfg: MoEConfig,
                  token_mask: Optional[Array] = None) -> Array:
    """Capacity-based dispatch via scatter/gather (dropless-style buffers).

    Per group of ``group_size`` tokens: each (token, slot) claims a position
    in its expert's capacity-C buffer (slot-major priority, overflow
    dropped); tokens are scattered into (E*C, D) buffers, experts run
    batched on (E, C, D), and a weighted gather combines. No (G, E, C)
    one-hot tensors are materialized — peak extra memory is the (E, C, D)
    buffer itself, and FLOPs overhead over the pure expert matmuls is ~0
    (vs 60-100%% for the classic GShard einsum dispatch; see EXPERIMENTS.md
    §Perf for the measured delta).

    ``token_mask`` (N,) bool: dead tokens (inactive decode slot rows,
    padding) neither claim a capacity position nor combine — without this,
    a dead token ahead in slot-major order silently displaces a live
    token's buffer slot and changes the live row's output."""
    n, d = x2d.shape
    token_mask = jnp.ones((n,), jnp.bool_) if token_mask is None \
        else token_mask.astype(jnp.bool_)
    gsz = min(cfg.group_size, n)
    n_groups = (n + gsz - 1) // gsz
    pad = n_groups * gsz - n
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
        top_p = jnp.pad(top_p, ((0, pad), (0, 0)))
        # padded tokens: keep indices valid; their combine weight is 0 and
        # (via token_mask) they never claim a capacity position
        top_i = jnp.pad(top_i, ((0, pad), (0, 0)))
        top_p = top_p * (jnp.arange(n_groups * gsz) < n)[:, None]
        token_mask = jnp.pad(token_mask, (0, pad))
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * k * gsz / e), 4)
    cap = (cap + 7) // 8 * 8   # MXU-friendly

    from repro.distributed.sharding import maybe_constrain

    # Shard the GROUP axis over the whole mesh when it divides evenly
    # (§Perf iteration 3): every device owns whole groups, expert weights
    # are gathered (they are small: E*3*d*f), and the d_ff-TP partial-sum
    # all-reduces of (E, C, d) buffers — the dominant MoE collective —
    # vanish. Equivalent semantics to more, smaller GShard groups.
    group_axes: tuple = ("dp",)
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            total = 1
            for ax in am.axis_names:
                total *= am.shape[ax]
            if n_groups % max(total, 1) == 0 and total > 1:
                group_axes = ("dp", "tp")
    except (AttributeError, KeyError, TypeError):
        # older jax without get_abstract_mesh / mesh objects missing
        # axis_names or shape lookups — fall back to dp-only grouping
        pass

    xg = maybe_constrain(x2d.reshape(n_groups, gsz, d), group_axes, None, None)
    pg = maybe_constrain(top_p.reshape(n_groups, gsz, k), group_axes, None, None)
    ig = maybe_constrain(top_i.reshape(n_groups, gsz, k), group_axes, None, None)
    mg = token_mask.reshape(n_groups, gsz)

    # expert weights enter the dispatch region gathered over the FSDP axis
    # (classic ZeRO-3: gather weights once per layer, never the token
    # buffers). With whole-mesh group sharding the weights are fully
    # replicated inside the region; otherwise d_ff stays tensor-parallel.
    w_tp = None if "tp" in group_axes else "tp"
    w_gate = maybe_constrain(p["w_gate"], None, None, w_tp)
    w_up = maybe_constrain(p["w_up"], None, None, w_tp)
    w_down = maybe_constrain(p["w_down"], None, w_tp, None)

    def per_group(xs, ps, ix, ms):
        # position of each (slot, token) in its expert buffer, slot-major;
        # dead tokens (ms False) claim nothing and scatter out of bounds
        flat_e = ix.T.reshape(k * gsz)                               # (kG,)
        live = jnp.tile(ms, (k,))                                    # (kG,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32) \
            * live[:, None].astype(jnp.float32)                      # (kG,E)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)      # (kG,)
        flat_idx = flat_e * cap + pos
        flat_idx = jnp.where(live & (pos < cap), flat_idx, e * cap)  # OOB -> drop
        # scatter tokens into expert buffers (device-local: the group axis
        # is vmapped with spmd_axis_name=dp, so these constraints pin every
        # intermediate to "this group's shard")
        x_rep = jnp.tile(xs, (k, 1))                                 # (kG,D)
        xb = jnp.zeros((e * cap, d), xs.dtype).at[flat_idx].set(
            x_rep, mode="drop")
        xb = maybe_constrain(xb, None, None)
        g = jnp.einsum("ecd,edf->ecf", xb.reshape(e, cap, d), w_gate.astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", xb.reshape(e, cap, d), w_up.astype(xb.dtype))
        h = jax.nn.silu(g) * u                                       # (E,C,F/tp)
        yb = jnp.einsum("ecf,efd->ecd", h, w_down.astype(h.dtype))
        yb = maybe_constrain(yb.reshape(e * cap, d), None, None)
        # gather + weighted combine
        yt = jnp.take(yb, jnp.clip(flat_idx, 0, e * cap - 1), axis=0)
        keep = ((pos < cap) & live)[:, None].astype(yt.dtype)
        w = ps.T.reshape(k * gsz, 1).astype(yt.dtype)
        contrib = (yt * keep * w).reshape(k, gsz, d)
        return jnp.sum(contrib, axis=0)

    # shard the mapped (group) axis so the dispatch scatter/gather and
    # expert buffers stay device-local
    spmd_axes = None
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            wanted = ("pod", "data", "model") if "tp" in group_axes else ("pod", "data")
            got = tuple(a for a in wanted if a in am.axis_names)
            spmd_axes = got if got else None
    except (AttributeError, KeyError, TypeError):
        # same probe as above: no abstract-mesh API -> unsharded vmap
        spmd_axes = None
    vm = jax.vmap(per_group, spmd_axis_name=spmd_axes) if spmd_axes else jax.vmap(per_group)
    y = vm(xg, pg, ig, mg)
    y = maybe_constrain(y, group_axes, None, None).reshape(n_groups * gsz, d)
    return y[:n] if pad else y


def moe_apply(p: Params, x: Array, cfg: MoEConfig, ctx: QuantContext = NO_QUANT,
              name: str = "moe", active: Optional[Array] = None,
              ) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, T, D) -> (y, aux_losses).

    ``active``: optional bool decode-slot mask, per-row (B,) or per-token
    (B, T) — the latter is the chunked-prefill tick, where a row's padding
    tail is dead. Dead tokens are masked out of the router outputs AND the
    dispatch capacity accounting, so a dead token cannot displace live
    tokens from expert buffers (its own output is garbage either way — the
    serving engine drops dead tokens' state writes)."""
    b, t, d = x.shape
    x2d = ctx.act(name + "/in", x.reshape(b * t, d))
    top_p, top_i, aux = _router(p, x2d, cfg, ctx, name)
    token_mask = None
    if active is not None:
        token_mask = active.reshape(b * t).astype(jnp.bool_) \
            if active.ndim == 2 else jnp.repeat(active.astype(jnp.bool_), t)
        top_p = top_p * token_mask[:, None].astype(top_p.dtype)
    if cfg.exec_mode == "dense":
        y = _moe_dense(p, x2d, top_p, top_i, cfg)
    else:
        y = _moe_dispatch(p, x2d, top_p, top_i, cfg, token_mask=token_mask)
    if cfg.n_shared_experts > 0:
        from repro.nn.mlp import mlp_apply
        y = y + mlp_apply(p["shared"], x2d, cfg.mlp_kind, ctx, name + "/shared")
    y = ctx.act(name + "/out", y)
    return y.reshape(b, t, d), aux
