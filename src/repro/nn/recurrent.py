"""RG-LRU and the Griffin/RecurrentGemma recurrent block (arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)              # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)              # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)    # diagonal recurrence, c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the affine maps
(h -> a h + b compose associatively), giving O(log T) depth — the TPU
adaptation of the paper's custom Pallas/linear-scan GPU kernel; decode
carries (h, conv_state) explicitly.

Note the RG-LRU's gates already give the head an explicit no-op path
(i_t -> 0), which is exactly what the Quantizable-Transformers paper adds
to softmax attention; see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import conv1d_apply, conv1d_init, linear_apply, linear_init
from repro.nn.module import Array, Params, split_keys
from repro.quant.qconfig import NO_QUANT, QuantContext

_C = 8.0  # Griffin's fixed recurrence sharpness


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int                 # recurrent width (= d_model for recurrentgemma)
    conv_width: int = 4
    a_init_min: float = 0.9    # Lambda init so a in [0.9, 0.999]
    a_init_max: float = 0.999


def rglru_init(key: Array, cfg: RGLRUConfig, dtype=jnp.float32) -> Params:
    ka, kx, kl = split_keys(key, 3)
    std = 1.0 / math.sqrt(cfg.width)
    u = jax.random.uniform(kl, (cfg.width,), minval=cfg.a_init_min ** 2,
                           maxval=cfg.a_init_max ** 2)
    # Lambda such that exp(-c*softplus(Lambda)) = sqrt(u)
    softplus_val = -0.5 * jnp.log(u) / _C
    lam = jnp.log(jnp.expm1(softplus_val))
    return {
        "w_a": linear_init(ka, cfg.width, cfg.width, std=std, dtype=dtype),
        "w_x": linear_init(kx, cfg.width, cfg.width, std=std, dtype=dtype),
        "lambda": lam.astype(jnp.float32),
    }


def _gates(p: Params, x: Array, ctx: QuantContext, name: str):
    r = jax.nn.sigmoid(linear_apply(p["w_a"], x, ctx, name + "/w_a").astype(jnp.float32))
    i = jax.nn.sigmoid(linear_apply(p["w_x"], x, ctx, name + "/w_x").astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r            # (B,T,D) f32
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    return a, gated_x


def rglru_scan(p: Params, x: Array, h0: Optional[Array] = None,
               ctx: QuantContext = NO_QUANT, name: str = "rglru"
               ) -> Tuple[Array, Array]:
    """Parallel form. x: (B, T, D) -> (y (B,T,D), h_last (B,D))."""
    a, b = _gates(p, x, ctx, name)
    if h0 is not None:
        # fold the carried state into the first step: h1 = a1 h0 + b1
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p: Params, x_t: Array, h: Array,
               ctx: QuantContext = NO_QUANT, name: str = "rglru"
               ) -> Tuple[Array, Array]:
    """Single decode step. x_t: (B, D); h: (B, D) f32."""
    a, b = _gates(p, x_t[:, None, :], ctx, name)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x_t.dtype), h_new


# --------------------------------------------------------------------------
# Griffin recurrent block: (linear, conv, RG-LRU) x (linear, GeLU) -> merge
# --------------------------------------------------------------------------
def griffin_block_init(key: Array, d_model: int, cfg: RGLRUConfig,
                       dtype=jnp.float32) -> Params:
    k1, k2, k3, k4, k5 = split_keys(key, 5)
    return {
        "in_x": linear_init(k1, d_model, cfg.width, bias=False, dtype=dtype),
        "in_gate": linear_init(k2, d_model, cfg.width, bias=False, dtype=dtype),
        "conv": conv1d_init(k3, cfg.width, cfg.conv_width, dtype=dtype),
        "rglru": rglru_init(k4, cfg, dtype=dtype),
        "out": linear_init(k5, cfg.width, d_model, bias=False, dtype=dtype),
    }


def griffin_block_apply(
    p: Params, x: Array, cfg: RGLRUConfig,
    state: Optional[dict] = None,
    ctx: QuantContext = NO_QUANT, name: str = "griffin",
) -> Tuple[Array, dict]:
    """x: (B, T, D). state: {"h": (B,W) f32, "conv": (B,w-1,W)} or None.

    Returns (y, new_state); pass T=1 slices with state for decode.
    """
    gate = jax.nn.gelu(linear_apply(p["in_gate"], x, ctx, name + "/in_gate"))
    u = linear_apply(p["in_x"], x, ctx, name + "/in_x")
    conv_state = None if state is None else state["conv"]
    u, conv_state = conv1d_apply(p["conv"], u, conv_state)
    h0 = None if state is None else state["h"]
    if x.shape[1] == 1 and state is not None:
        y_r, h_last = rglru_step(p["rglru"], u[:, 0, :], h0, ctx, name + "/rglru")
        y_r = y_r[:, None, :]
    else:
        y_r, h_last = rglru_scan(p["rglru"], u, h0, ctx, name + "/rglru")
    merged = ctx.act(name + "/merged", y_r * gate)
    y = linear_apply(p["out"], merged, ctx, name + "/out")
    return y, {"h": h_last, "conv": conv_state}


def griffin_init_state(batch: int, cfg: RGLRUConfig, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.width), dtype),
    }
