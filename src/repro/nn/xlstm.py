"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, recurrent), with exponential gating + stabilizers.

TPU adaptation: the official CUDA kernels are replaced by
  * a *chunkwise-parallel* mLSTM (intra-chunk quadratic attention-like form,
    inter-chunk recurrent state carried by lax.scan) — O(T·L) work, MXU
    friendly, exact w.r.t. the recurrent definition (validated against
    ``mlstm_recurrent_ref`` in tests);
  * an lax.scan sLSTM (inherently sequential, like the original).

The paper's clipped softmax does NOT apply here (no softmax over tokens);
the cells' own output gates provide the explicit no-op path. See DESIGN.md.

Stabilized mLSTM recurrence (per head):
    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = e^{logf_t + m_{t-1} - m_t} C_{t-1} + e^{logi_t - m_t} k_t v_t^T
    n_t = e^{logf_t + m_{t-1} - m_t} n_{t-1} + e^{logi_t - m_t} k_t
    h_t = (q_t C_t) / max(|q_t · n_t|, e^{-m_t}),   q scaled by d_k^-0.5
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import conv1d_apply, conv1d_init, linear_apply, linear_init
from repro.nn.module import Array, Params, split_keys
from repro.quant.qconfig import NO_QUANT, QuantContext


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 4.0 / 3.0
    conv_width: int = 4
    chunk_size: int = 64

    @property
    def d_inner(self) -> int:
        return int(self.mlstm_proj_factor * self.d_model)

    @property
    def dh_inner(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def dh_model(self) -> int:
        return self.d_model // self.n_heads


# --------------------------------------------------------------------------
# mLSTM cell
# --------------------------------------------------------------------------
def mlstm_recurrent_ref(q, k, v, logi, logf, state=None):
    """Sequential oracle. q,k,v: (B,T,H,D); logi/logf: (B,T,H).

    Returns (h (B,T,H,D), state = (C (B,H,D,D), n (B,H,D), m (B,H)))."""
    b, t, h, d = q.shape
    scale = d ** -0.5
    if state is None:
        C = jnp.zeros((b, h, d, d), jnp.float32)
        n = jnp.zeros((b, h, d), jnp.float32)
        m = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        C, n, m = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = fp[..., None] * n + ip[..., None] * kt
        qs = qt * scale
        num = jnp.einsum("bhd,bhde->bhe", qs, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n))
        den = jnp.maximum(den, jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = (
        jnp.moveaxis(q.astype(jnp.float32), 1, 0),
        jnp.moveaxis(k.astype(jnp.float32), 1, 0),
        jnp.moveaxis(v.astype(jnp.float32), 1, 0),
        jnp.moveaxis(logi.astype(jnp.float32), 1, 0),
        jnp.moveaxis(logf.astype(jnp.float32), 1, 0),
    )
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


def mlstm_chunkwise(q, k, v, logi, logf, chunk: int = 64, state=None):
    """Chunkwise-parallel mLSTM, exact match of the recurrent form.

    q,k,v: (B,T,H,D); logi/logf: (B,T,H). Returns (h, final_state)."""
    b, t, h, d = q.shape
    scale = d ** -0.5
    L = min(chunk, t)
    n_chunks = (t + L - 1) // L
    pad = n_chunks * L - t
    if pad:
        padT = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v, logi, logf = map(padT, (q, k, v, logi, logf))
        # padded steps: logf = 0 (keep state), logi = -inf (no input)
        mask_t = jnp.arange(n_chunks * L) < t
        logi = jnp.where(mask_t[None, :, None], logi, -1e30)
        logf = jnp.where(mask_t[None, :, None], logf, 0.0)

    def rs(x):  # (B, n_chunks, L, H, ...) -> scan over chunks
        return jnp.moveaxis(x.reshape(b, n_chunks, L, *x.shape[2:]), 1, 0)

    qc, kc, vc = rs(q.astype(jnp.float32)), rs(k.astype(jnp.float32)), rs(v.astype(jnp.float32))
    lic, lfc = rs(logi.astype(jnp.float32)), rs(logf.astype(jnp.float32))

    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]          # j <= i

    def chunk_step(carry, inp):
        C, n, m_prev = carry
        qb, kb, vb, li, lf = inp                   # (B,L,H,*)
        F = jnp.cumsum(lf, axis=1)                 # inclusive cumsum (B,L,H)
        G = li - F                                 # (B,L,H)
        Mi = jax.lax.cummax(G, axis=1)             # cummax over j<=i
        m_intra = F + Mi
        m_inter = F + m_prev[:, None, :]
        m_i = jnp.maximum(m_intra, m_inter)        # (B,L,H)
        # decay matrix D_ij = exp(F_i - F_j + li_j - m_i), j<=i
        expo = (
            F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
            - m_i[:, :, None, :]
        )                                          # (B,i,j,H)
        D = jnp.where(causal[None, :, :, None], jnp.exp(expo), 0.0)
        S = jnp.einsum("bihd,bjhd->bijh", qb * scale, kb) * D
        inter_w = jnp.exp(m_inter - m_i)           # (B,L,H)
        num = jnp.einsum("bijh,bjhe->bihe", S, vb) + inter_w[..., None] * jnp.einsum(
            "bihd,bhde->bihe", qb * scale, C
        )
        # denominator q_i·n_i = sum_j D_ij (q_i·k_j) + inter_w * (q_i·n_prev);
        # the first term is exactly sum_j S_ij.
        den = jnp.sum(S, axis=2) + inter_w * jnp.einsum("bihd,bhd->bih", qb * scale, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
        hb = num / den[..., None]
        # ---- state update to chunk end ----
        F_tot = F[:, -1, :]                        # (B,H)
        m_end = jnp.maximum(F_tot + m_prev, F_tot + Mi[:, -1, :])
        w_prev = jnp.exp(F_tot + m_prev - m_end)   # (B,H)
        w_j = jnp.exp(F_tot[:, None, :] - F + li - m_end[:, None, :])  # (B,L,H)
        C_new = w_prev[:, :, None, None] * C + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", w_j, kb, vb
        )
        n_new = w_prev[..., None] * n + jnp.einsum("bjh,bjhd->bhd", w_j, kb)
        return (C_new, n_new, m_end), hb

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, n_chunks * L, h, d)
    return hs[:, :t], (C, n, m)


# --------------------------------------------------------------------------
# sLSTM cell (sequential)
# --------------------------------------------------------------------------
def slstm_scan(z_in, i_in, f_in, o_in, r_params, n_heads: int, state=None):
    """Stabilized sLSTM with per-head recurrent connections.

    z/i/f/o_in: (B, T, D) pre-activations from the input path.
    r_params: {"rz","ri","rf","ro"}: (H, dh, dh) block-diag recurrences.
    Returns (h (B,T,D), state)."""
    b, t, d = z_in.shape
    dh = d // n_heads

    def heads(x):  # (B, D) -> (B, H, dh)
        return x.reshape(b, n_heads, dh)

    if state is None:
        c = jnp.zeros((b, n_heads, dh), jnp.float32)
        n = jnp.zeros((b, n_heads, dh), jnp.float32)
        m = jnp.full((b, n_heads, dh), -1e30, jnp.float32)
        h = jnp.zeros((b, n_heads, dh), jnp.float32)
    else:
        c, n, m, h = state

    def rmat(name, h):  # recurrent contribution (B,H,dh)
        return jnp.einsum("bhd,hde->bhe", h, r_params[name].astype(jnp.float32))

    def step(carry, inp):
        c, n, m, h = carry
        zt, it, ft, ot = inp
        z = jnp.tanh(heads(zt).astype(jnp.float32) + rmat("rz", h))
        i_pre = heads(it).astype(jnp.float32) + rmat("ri", h)
        f_pre = heads(ft).astype(jnp.float32) + rmat("rf", h)
        o = jax.nn.sigmoid(heads(ot).astype(jnp.float32) + rmat("ro", h))
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(i_pre - m_new)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = o * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
        return (c_new, n_new, m_new, h_new), h_new

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (z_in, i_in, f_in, o_in))
    (c, n, m, h), hs = jax.lax.scan(step, (c, n, m, h), xs)
    return jnp.moveaxis(hs, 0, 1).reshape(b, t, d), (c, n, m, h)


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------
def headwise_rmsnorm_init(n_heads: int, dh: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((n_heads, dh), dtype)}


def headwise_rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    """x: (B, T, H, dh) — GroupNorm-per-head as in the xLSTM paper."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def mlstm_block_init(key: Array, cfg: XLSTMConfig, dtype=jnp.float32) -> Params:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = cfg.dh_inner
    ks = split_keys(key, 8)
    return {
        "up": linear_init(ks[0], d, 2 * di, bias=False, dtype=dtype),
        "conv": conv1d_init(ks[1], di, cfg.conv_width, dtype=dtype),
        "q": linear_init(ks[2], di, di, bias=False, dtype=dtype),
        "k": linear_init(ks[3], di, di, bias=False, dtype=dtype),
        "v": linear_init(ks[4], di, di, bias=False, dtype=dtype),
        "ifgate": linear_init(ks[5], di, 2 * h, dtype=dtype),   # logi/logf preacts
        "norm": headwise_rmsnorm_init(h, dh, dtype),
        "down": linear_init(ks[6], di, d, bias=False, dtype=dtype),
    }


def mlstm_block_apply(p: Params, x: Array, cfg: XLSTMConfig,
                      state: Optional[dict] = None,
                      ctx: QuantContext = NO_QUANT, name: str = "mlstm"
                      ) -> Tuple[Array, dict]:
    b, t, d = x.shape
    h, dh, di = cfg.n_heads, cfg.dh_inner, cfg.d_inner
    up = linear_apply(p["up"], x, ctx, name + "/up")
    u, z = jnp.split(up, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    uc, conv_state = conv1d_apply(p["conv"], u, conv_state)
    uc = jax.nn.silu(uc)
    q = linear_apply(p["q"], uc, ctx, name + "/q").reshape(b, t, h, dh)
    k = linear_apply(p["k"], uc, ctx, name + "/k").reshape(b, t, h, dh)
    v = linear_apply(p["v"], u, ctx, name + "/v").reshape(b, t, h, dh)
    gates = linear_apply(p["ifgate"], uc, ctx, name + "/ifgate").astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)              # (B,T,H)
    logi = i_pre                                             # exponential input gate
    logf = jax.nn.log_sigmoid(f_pre)
    cell_state = None if state is None else state["cell"]
    if t == 1 and state is not None:
        hs, cell_state = mlstm_recurrent_ref(q, k, v, logi, logf, cell_state)
    else:
        hs, cell_state = mlstm_chunkwise(q, k, v, logi, logf, cfg.chunk_size, cell_state)
    hs = headwise_rmsnorm(p["norm"], hs.astype(x.dtype)).reshape(b, t, di)
    out = ctx.act(name + "/gated", hs * jax.nn.silu(z))
    y = linear_apply(p["down"], out, ctx, name + "/down")
    return y, {"conv": conv_state, "cell": cell_state}


def slstm_block_init(key: Array, cfg: XLSTMConfig, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dh = cfg.dh_model
    # round to a 64-multiple so the width shards over 16-way TP
    dff = (int(cfg.slstm_ff_factor * d) + 63) // 64 * 64
    ks = split_keys(key, 9)
    r = lambda kk: (0.1 / math.sqrt(dh) * jax.random.normal(kk, (h, dh, dh))).astype(dtype)
    return {
        "conv": conv1d_init(ks[0], d, cfg.conv_width, dtype=dtype),
        "zifo": linear_init(ks[1], d, 4 * d, dtype=dtype),
        "rz": r(ks[2]), "ri": r(ks[3]), "rf": r(ks[4]), "ro": r(ks[5]),
        "norm": headwise_rmsnorm_init(h, dh, dtype),
        "ff_up": linear_init(ks[6], d, dff, bias=False, dtype=dtype),
        "ff_gate": linear_init(ks[7], d, dff, bias=False, dtype=dtype),
        "ff_down": linear_init(ks[8], dff, d, bias=False, dtype=dtype),
    }


def slstm_block_apply(p: Params, x: Array, cfg: XLSTMConfig,
                      state: Optional[dict] = None,
                      ctx: QuantContext = NO_QUANT, name: str = "slstm"
                      ) -> Tuple[Array, dict]:
    b, t, d = x.shape
    conv_state = None if state is None else state["conv"]
    xc, conv_state = conv1d_apply(p["conv"], x, conv_state)
    xc = jax.nn.silu(xc)
    zifo = linear_apply(p["zifo"], xc, ctx, name + "/zifo")
    z_in, i_in, f_in, o_in = jnp.split(zifo, 4, axis=-1)
    cell_state = None if state is None else state["cell"]
    hs, cell_state = slstm_scan(z_in, i_in, f_in, o_in,
                                {k: p[k] for k in ("rz", "ri", "rf", "ro")},
                                cfg.n_heads, cell_state)
    hs = headwise_rmsnorm(
        p["norm"], hs.reshape(b, t, cfg.n_heads, cfg.dh_model).astype(x.dtype)
    ).reshape(b, t, d)
    g = jax.nn.gelu(linear_apply(p["ff_gate"], hs, ctx, name + "/ff_gate"))
    u = linear_apply(p["ff_up"], hs, ctx, name + "/ff_up")
    y = linear_apply(p["ff_down"], ctx.act(name + "/ff_act", g * u), ctx, name + "/ff_down")
    return y, {"conv": conv_state, "cell": cell_state}


def xlstm_init_state(batch: int, kind: str, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    if kind == "mlstm":
        h, dh = cfg.n_heads, cfg.dh_inner
        return {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
            "cell": (
                jnp.zeros((batch, h, dh, dh), jnp.float32),
                jnp.zeros((batch, h, dh), jnp.float32),
                jnp.full((batch, h), -1e30, jnp.float32),
            ),
        }
    h, dh = cfg.n_heads, cfg.dh_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model), dtype),
        "cell": (
            jnp.zeros((batch, h, dh), jnp.float32),
            jnp.zeros((batch, h, dh), jnp.float32),
            jnp.full((batch, h, dh), -1e30, jnp.float32),
            jnp.zeros((batch, h, dh), jnp.float32),
        ),
    }
