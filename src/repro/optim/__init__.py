from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.compress import ErrorFeedbackState, compress_grads, ef_init
from repro.optim.schedule import (
    constant,
    linear_warmup_cosine,
    linear_warmup_linear_decay,
)

__all__ = [
    "AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm",
    "ErrorFeedbackState", "compress_grads", "ef_init",
    "constant", "linear_warmup_cosine", "linear_warmup_linear_decay",
]
