"""AdamW with decoupled weight decay (Loshchilov & Hutter), built from
scratch (no optax in this environment).

Includes the paper's OPT trick (App. B.3): optionally extending weight
decay to LayerNorm scales, which alone dampens outliers — controlled by
``decay_norm_scales``. Weight-decay masking follows the usual convention
(no decay on biases / norm params) unless overridden.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Array, Params, flatten_params

NO_DECAY_DEFAULT = (r".*(/b|/bias|/scale|lambda)$",)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4                  # peak LR; schedule multiplies this
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip_norm: Optional[float] = 1.0
    decay_norm_scales: bool = False   # paper App. B.3 ("LN gamma wd")
    no_decay_patterns: Tuple[str, ...] = NO_DECAY_DEFAULT


class AdamWState(NamedTuple):
    step: Array
    mu: Params
    nu: Params


def _decay_mask(params: Params, cfg: AdamWConfig) -> Params:
    """Pytree of {0,1} floats: 1 where weight decay applies."""
    pats = cfg.no_decay_patterns
    if cfg.decay_norm_scales:
        # keep biases un-decayed but decay norm scales
        pats = (r".*/b$", r".*/bias$", r".*lambda$")
    flat = dict(flatten_params(params))
    masks = {
        path: 0.0 if any(re.match(p, path) for p in pats) else 1.0
        for path in flat
    }
    # rebuild tree in params' structure
    leaves_with_path = list(flatten_params(params))
    mask_leaves = [masks[path] for path, _ in leaves_with_path]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, mask_leaves)


def global_norm(tree: Params) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_init(params: Params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(
    grads: Params,
    state: AdamWState,
    params: Params,
    cfg: AdamWConfig,
    lr_scale: Array = 1.0,
) -> Tuple[Params, AdamWState, Dict[str, Array]]:
    """Returns (new_params, new_state, metrics)."""
    metrics: Dict[str, Array] = {}
    if cfg.grad_clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mask = _decay_mask(params, cfg)

    def upd(g, m, v, p, dm):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        delta = delta + cfg.weight_decay * dm * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params, mask)
    # unzip the 3-tuples
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    metrics["update_norm"] = global_norm(
        jax.tree_util.tree_map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                               new_params, params))
    return new_params, AdamWState(step, new_mu, new_nu), metrics
