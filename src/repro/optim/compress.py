"""INT8 gradient compression with error feedback — the cross-pod DP
all-reduce trick for 1000+ node scale.

Scheme (1-bit-Adam-style generalized to int8):
  1. g_corrected = g + error_residual
  2. per-tensor symmetric int8 quantize -> what actually crosses the
     (slow, cross-pod DCI) link: 4x fewer bytes than f32 (2x vs bf16)
  3. error_residual' = g_corrected - dequant(q)

Inside jit the quantize/dequantize pair brackets the ``psum`` so XLA's
all-reduce operates on the int8-representable values; on real multi-pod
topologies this is combined with `jax.lax.psum` over the "pod" axis only
(intra-pod reduction stays full precision). The roofline win: cross-pod
collective bytes / 4.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Params


class ErrorFeedbackState(NamedTuple):
    residual: Params


def ef_init(params: Params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    )


def _q_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(
    grads: Params, ef: ErrorFeedbackState
) -> Tuple[Params, ErrorFeedbackState]:
    """Returns (int8-representable grads as f32, new error state)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = _q_int8(g32)
        deq = q.astype(jnp.float32) * s
        return deq, g32 - deq

    out = jax.tree_util.tree_map(one, grads, ef.residual)
    deq = jax.tree_util.tree_map(lambda t: t[0], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return deq, ErrorFeedbackState(res)
