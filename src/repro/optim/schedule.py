"""LR schedules as pure functions step -> multiplier (peak LR lives in
AdamWConfig). Matches the paper's setups: linear warmup + linear decay
(BERT/OPT pre-training) and cosine with warmup (ViT)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def linear_warmup_linear_decay(warmup: int, total: int) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        decay = (total - step) / jnp.maximum(total - warmup, 1)
        return jnp.clip(jnp.minimum(warm, decay), 0.0, 1.0)
    return fn


def linear_warmup_cosine(warmup: int, total: int, min_frac: float = 0.01) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant() -> Schedule:
    return lambda step: jnp.ones((), jnp.float32)
