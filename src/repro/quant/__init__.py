"""Quantization substrate: fake-quant, range estimation, PTQ driver."""
from repro.quant.quantizer import (
    QuantSpec,
    dequantize,
    fake_quant,
    quantization_error,
    quantize,
    scale_zero_point,
)
from repro.quant.ranges import (
    MinMaxEstimator,
    MSEEstimator,
    PercentileEstimator,
    RangeEstimator,
    RunningMinMaxEstimator,
    make_estimator,
)
from repro.quant.qconfig import NO_QUANT, QConfig, QuantContext
from repro.quant.ptq import calibrate, evaluate_perplexity, make_quantized_apply, ptq_sweep

__all__ = [
    "QuantSpec", "dequantize", "fake_quant", "quantization_error", "quantize",
    "scale_zero_point",
    "MinMaxEstimator", "MSEEstimator", "PercentileEstimator", "RangeEstimator",
    "RunningMinMaxEstimator", "make_estimator",
    "NO_QUANT", "QConfig", "QuantContext",
    "calibrate", "evaluate_perplexity", "make_quantized_apply", "ptq_sweep",
]
from repro.quant.int8_weights import (  # noqa: E402
    attach_int8_weights,
    build_int8_cache,
    int8_cache_bytes,
    linear_int8,
)
from repro.quant.kv_cache import kv_dequant, kv_quant  # noqa: E402

__all__ += ["attach_int8_weights", "build_int8_cache", "int8_cache_bytes",
            "linear_int8", "kv_quant", "kv_dequant"]
