"""Hardware-path W8A8 serving: convert calibrated FP params into an int8
weight cache and run linears through the Pallas MXU kernel.

``fake_quant`` (quant/quantizer.py) *simulates* integer inference in float —
that is the paper's evaluation protocol. This module is the deployment
counterpart: weights are stored as actual int8 (+ per-tensor scale),
activations are quantized on the fly inside the kernel, and matmuls run
int8 x int8 -> int32 (repro.kernels.int8_matmul). The two paths agree to
rounding (tests/test_int8_serving.py) — agreement is only possible because
the paper's methods removed the activation outliers.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul import int8_matmul, quantize_weights_int8
from repro.nn.module import flatten_params

Array = jax.Array

# param paths worth int8-caching: the big matmul weights
_MATMUL_W = re.compile(
    r".*/(q|k|v|o|up|gate|down|in_x|in_gate|out|w_a|w_x|zifo|ff_up|ff_gate|"
    r"ff_down)/w$|.*lm_head/w$|.*embed/table$")


def build_int8_cache(params: Any, skip: Tuple[str, ...] = (r".*lm_head.*",)
                     ) -> Dict[str, Tuple[Array, Array]]:
    """Quantize every matmul weight to (int8 tensor, f32 scale)."""
    cache: Dict[str, Tuple[Array, Array]] = {}
    for path, leaf in flatten_params(params):
        if leaf.ndim != 2 or not _MATMUL_W.match(path):
            continue
        if any(re.match(p, path) for p in skip):
            continue
        wq, s = quantize_weights_int8(leaf)
        cache[path] = (wq, s)
    return cache


def int8_cache_bytes(cache: Dict[str, Tuple[Array, Array]]) -> int:
    return sum(int(wq.size) for wq, _ in cache.values())


def linear_int8(cache: Dict[str, Tuple[Array, Array]], path: str,
                x: Array, bias: Array = None, interpret: bool = True) -> Array:
    """Run one cached linear through the integer kernel."""
    wq, s = cache[path]
    lead = x.shape[:-1]
    y = int8_matmul(x.reshape(-1, x.shape[-1]), wq, s, interpret=interpret)
    y = y.reshape(*lead, wq.shape[1])
    if bias is not None:
        y = y + bias
    return y
