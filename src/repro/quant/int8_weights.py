"""Hardware-path W8A8 serving: convert calibrated FP params into an int8
weight cache and run linears through the Pallas MXU kernel.

``fake_quant`` (quant/quantizer.py) *simulates* integer inference in float —
that is the paper's evaluation protocol. This module is the deployment
counterpart: weights are stored as actual int8 (+ per-tensor scale),
activations are quantized on the fly inside the kernel, and matmuls run
int8 x int8 -> int32 (repro.kernels.int8_matmul). The two paths agree to
rounding (tests/test_int8_serving.py) — agreement is only possible because
the paper's methods removed the activation outliers.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul import int8_matmul, quantize_weights_int8
from repro.nn.module import flatten_params

Array = jax.Array

# param paths worth int8-caching: the big matmul weights
_MATMUL_W = re.compile(
    r".*/(q|k|v|o|up|gate|down|in_x|in_gate|out|w_a|w_x|zifo|ff_up|ff_gate|"
    r"ff_down)/w$|.*lm_head/w$|.*embed/table$")


def build_int8_cache(params: Any, skip: Tuple[str, ...] = (r".*lm_head.*",)
                     ) -> Dict[str, Tuple[Array, Array]]:
    """Quantize every matmul weight to (int8 tensor, f32 scale)."""
    cache: Dict[str, Tuple[Array, Array]] = {}
    for path, leaf in flatten_params(params):
        if leaf.ndim != 2 or not _MATMUL_W.match(path):
            continue
        if any(re.match(p, path) for p in skip):
            continue
        wq, s = quantize_weights_int8(leaf)
        cache[path] = (wq, s)
    return cache


def int8_cache_bytes(cache: Dict[str, Tuple[Array, Array]]) -> int:
    return sum(int(wq.size) for wq, _ in cache.values())


def attach_int8_weights(params: Any, skip: Tuple[str, ...] = (r".*lm_head.*",)
                        ) -> Any:
    """Return a params tree with ``w_q8``/``w_scale`` leaves attached beside
    every matmul weight ``w``.

    Attaching to the tree (rather than a side table keyed by site name) is
    what makes the serving W8A8 path correct for every layer: site names in
    ``models.transformer.group_apply`` repeat across groups
    (``layer_attn0`` in every group), so a name-keyed cache would collide,
    while params paths are unique. It also composes with scanned configs:
    a stacked ``(G, K, N)`` weight gets a stacked ``(G, K, N)`` int8 leaf +
    ``(G,)`` per-layer scales, and the unrolled apply's ``tree_slice``
    carves out each layer's pair alongside its fp weight. ``linear_apply``
    routes through the integer kernel whenever the ctx is in 'int8' mode
    and ``w_q8`` is present."""
    def walk(node: Any, prefix: str) -> Any:
        if isinstance(node, (list, tuple)):
            return [walk(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        if not isinstance(node, dict):
            return node
        out = {k: walk(v, f"{prefix}/{k}" if prefix else k)
               for k, v in node.items()}
        w = node.get("w")
        wpath = f"{prefix}/w" if prefix else "w"
        if (w is not None and not isinstance(w, (dict, list, tuple))
                and getattr(w, "ndim", 0) in (2, 3)
                and _MATMUL_W.match(wpath)
                and not any(re.match(p, wpath) for p in skip)):
            if w.ndim == 2:
                wq, s = quantize_weights_int8(w)
            else:  # scanned stacked groups: per-layer symmetric scales
                wq, s = jax.vmap(quantize_weights_int8)(w)
            out["w_q8"], out["w_scale"] = wq, s
        return out

    return walk(params, "")


def linear_int8(cache: Dict[str, Tuple[Array, Array]], path: str,
                x: Array, bias: Array = None, interpret: bool = True) -> Array:
    """Run one cached linear through the integer kernel."""
    wq, s = cache[path]
    lead = x.shape[:-1]
    y = int8_matmul(x.reshape(-1, x.shape[-1]), wq, s, interpret=interpret)
    y = y.reshape(*lead, wq.shape[1])
    if bias is not None:
        y = y + bias
    return y
