"""Int8 paged-KV quantization: per-block scale vectors for the block pools.

The paged serving cache (``models.transformer.init_paged_cache``) stores K/V
in global block pools ``(num_blocks, block_size, Hkv, Dh)``. With
``kv_int8=True`` the pools hold int8 and each pool block carries a *scale
vector* ``(num_blocks, block_size)`` — one f32 scale per token slot of the
block, symmetric int8 over that token's (Hkv, Dh) values:

    scale[nb, s] = max|kv[nb, s]| / 127        q = round(kv / scale)

Why one scale per slot instead of one scalar per block: a block fills
incrementally (chunked prefill writes a few tokens per tick), so a scalar
block scale would have to GROW as larger tokens arrive, requantizing the
already-written int8 values. That requantization chain depends on how the
prompt was chunked — it would break the engine's bitwise-invariance
contracts (chunk size, slot assignment, preemption-resume; see
tests/test_chunked_prefill.py) — and a recycled block would inherit the
previous occupant's amax. Per-slot scales make quantization write-once:
each token is quantized exactly once from its fp value in the same masked
scatter that writes the pool, so the stored bits are a pure function of
(token value, logical position) — the same staleness argument that lets
recycled blocks keep garbage KV applies verbatim to garbage scales. The
scale vector still lives and travels *per block* (it rides the block-table
DMA next to its pool block in the Pallas kernel), at 4 bytes per slot
against ``Hkv * Dh`` bytes of int8 payload.

Swapped preemption gets the same guarantee for free: the scale vectors are
batch-free *pool* leaves exactly like the int8 K/V pools, so the
scheduler's swap-out copies a victim's scale rows to host alongside its
blocks and swap-in restores both into freshly allocated block ids
(``serving.scheduler.SwappedState``). Because the stored bits are already
a pure function of (token value, logical position), a swap round-trip is
bit-identical to never having been preempted — which is what lets
tests/test_slo_serving.py assert swap-resume == recompute-resume ==
unpreempted, bitwise, on the int8-KV engine.

Prefix sharing (``serving.prefix_cache``) rides on exactly this choice: a
cached prompt block can be mapped into ANOTHER request's block table only
because its int8 bits + per-token scales depend on nothing but the tokens
and positions the trie keys it by. A scalar per-block scale would have
made shared blocks owner-history-dependent (whoever wrote last set the
amax) and copy-on-write divergence lossy; per-slot scales make a shared
read bitwise-equal to the cold prefill it replaced, and a CoW block copy
(``models.transformer.copy_pool_blocks``) is exact because the scale
vector is copied verbatim alongside the int8 payload.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# symmetric int8 over [-127, 127]; scale floor keeps all-zero tokens exact
KV_QMAX = 127.0
KV_EPS = 1e-8


def kv_quant(x: Array) -> Tuple[Array, Array]:
    """Quantize ``(..., Hkv, Dh)`` KV values to (int8 values, (...,) scales).

    The last two axes (heads, head dim) share one scale — the per-token
    granularity of the pool's per-block scale vectors."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    scale = jnp.maximum(amax / KV_QMAX, KV_EPS)
    q = jnp.clip(jnp.round(xf / scale[..., None, None]), -KV_QMAX, KV_QMAX
                 ).astype(jnp.int8)
    return q, scale


def kv_dequant(q: Array, scale: Array) -> Array:
    """Inverse of ``kv_quant``: (..., Hkv, Dh) int8 + (...,) scales -> f32."""
    return q.astype(jnp.float32) * scale[..., None, None].astype(jnp.float32)
