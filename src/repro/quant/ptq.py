"""Post-training quantization driver (paper Section 5 'Quantization setup').

Pipeline:
  1. ``calibrate``      — stream a few batches through the FP model with a
     QuantContext in 'collect' mode (un-jitted; sites record ranges).
  2. ``ctx.finalize()`` — estimators close into static (s, z).
  3. ``quantized_apply``— jit-able forward with fake-quant at every site.

The driver is model-agnostic: it only needs an ``apply(params, batch, ctx)``
callable, which every model in ``repro.models`` provides.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.qconfig import QConfig, QuantContext

Array = jax.Array
ApplyFn = Callable[..., Array]


def calibrate(
    apply_fn: ApplyFn,
    params,
    batches: Iterable,
    qconfig: QConfig,
    num_batches: int = 16,
) -> QuantContext:
    """Run `num_batches` through the FP network recording ranges (paper uses
    16 batches with running min-max, momentum 0.9)."""
    ctx = QuantContext(qconfig, mode="collect")
    for i, batch in enumerate(batches):
        if i >= num_batches:
            break
        apply_fn(params, batch, ctx)
    ctx.finalize()
    return ctx


def make_quantized_apply(apply_fn: ApplyFn, ctx: QuantContext, jit: bool = True):
    """Close the calibrated context over the apply function."""
    def q_apply(params, batch):
        return apply_fn(params, batch, ctx)
    return jax.jit(q_apply) if jit else q_apply


def evaluate_perplexity(
    loss_fn: Callable,
    params,
    batches: Iterable,
    ctx: Optional[QuantContext] = None,
    max_batches: int = 32,
) -> float:
    """Average token perplexity of (optionally quantized) model.

    ``loss_fn(params, batch, ctx) -> (sum_nll, n_tokens)``.
    """
    total_nll, total_tok = 0.0, 0
    for i, batch in enumerate(batches):
        if i >= max_batches:
            break
        nll, n = loss_fn(params, batch, ctx)
        total_nll += float(nll)
        total_tok += int(n)
    return float(jnp.exp(total_nll / max(total_tok, 1)))


def ptq_sweep(
    apply_fn: ApplyFn,
    loss_fn: Callable,
    params,
    calib_batches: Callable[[], Iterable],
    eval_batches: Callable[[], Iterable],
    qconfigs: Dict[str, QConfig],
    seeds: Tuple[int, ...] = (0, 1, 2),
) -> Dict[str, Dict[str, float]]:
    """Paper-protocol PTQ: repeat each setting over random calibration
    subsets (3 seeds in the paper) and report mean/std perplexity."""
    import numpy as np

    results: Dict[str, Dict[str, float]] = {}
    for name, qc in qconfigs.items():
        ppls = []
        for seed in seeds:
            ctx = calibrate(apply_fn, params, calib_batches(), qc)
            ppl = evaluate_perplexity(loss_fn, params, eval_batches(), ctx)
            ppls.append(ppl)
        results[name] = {
            "ppl_mean": float(np.mean(ppls)),
            "ppl_std": float(np.std(ppls)),
        }
    return results
