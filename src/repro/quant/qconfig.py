"""Model-level quantization configuration + the QuantContext threaded
through model ``apply``.

The paper's PTQ protocol (Section 5, App. C.4):
  * quantize ALL weights and ALL activations (inputs AND outputs of ops),
  * symmetric uniform weights / asymmetric uniform activations,
  * static activation ranges from a few calibration batches,
  * skip the final LM-head linear (BERT/OPT).

``QuantContext`` is how the model graph exposes quantization sites without a
module framework: every layer calls ``ctx.act(name, x)`` on activations and
``ctx.weight(name, w)`` on parameters right before use. The context is one
of three modes:

  off      — identity (training / FP evaluation)
  collect  — record tensors for range estimation (run UN-jitted)
  apply    — fake-quantize using finalized (s, z)  (jit-safe; scales are
             closed-over constants)
  int8     — hardware W8A8: ``act``/``weight`` are identity (no float
             fake-quant anywhere); instead, linears that carry attached
             int8 weights (quant.int8_weights.attach_int8_weights) pull
             their STATIC input (s, z) via ``act_qparams`` and run the
             integer kernel. Reached from 'apply' via ``use_int8_runtime``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.quantizer import QuantSpec, fake_quant, scale_zero_point
from repro.quant.ranges import RangeEstimator, make_estimator

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QConfig:
    """What to quantize and how (one per experiment row, e.g. 'W8A8')."""

    weight_bits: int = 8
    act_bits: int = 8
    weight_estimator: str = "minmax"      # "minmax" | "mse"
    act_estimator: str = "running_minmax" # + "percentile", "mse"
    act_estimator_kwargs: tuple = ()      # e.g. (("percentile", 99.999),)
    skip_patterns: Tuple[str, ...] = (r".*lm_head.*",)  # final linear skipped
    per_channel_weights: bool = False      # paper uses per-tensor

    @property
    def name(self) -> str:
        return f"W{self.weight_bits}A{self.act_bits}"

    def weight_spec(self, ndim: int = 2) -> QuantSpec:
        axis = (ndim - 1) if self.per_channel_weights else None
        return QuantSpec(bits=self.weight_bits, symmetric=True, per_channel_axis=axis)

    def act_spec(self) -> QuantSpec:
        return QuantSpec(bits=self.act_bits, symmetric=False)

    def skipped(self, name: str) -> bool:
        return any(re.match(p, name) for p in self.skip_patterns)


class QuantContext:
    """Threaded through model.apply; see module docstring."""

    def __init__(self, qconfig: Optional[QConfig], mode: str = "off") -> None:
        assert mode in ("off", "collect", "apply", "int8")
        self.qconfig = qconfig
        self.mode = mode if qconfig is not None else "off"
        self._estimators: Dict[str, RangeEstimator] = {}
        self._ranges: Dict[str, Tuple[Array, Array]] = {}
        # site -> (scale, zero) python floats, precomputed by
        # use_int8_runtime — act_qparams may be called inside a jit trace,
        # where even concrete range arrays become tracers, so the floats
        # must exist before tracing starts
        self._act_qp: Dict[str, Tuple[float, float]] = {}

    # -- calibration ------------------------------------------------------
    def _estimator_for(self, name: str, spec: QuantSpec, kind: str) -> RangeEstimator:
        if name not in self._estimators:
            kw = dict(self.qconfig.act_estimator_kwargs) if not spec.symmetric else {}
            self._estimators[name] = make_estimator(kind, spec, **kw)
        return self._estimators[name]

    def finalize(self) -> None:
        """Close all estimators into static (s, z); switch to 'apply'."""
        for name, est in self._estimators.items():
            self._ranges[name] = est.finalize()
        self.mode = "apply"

    @property
    def ranges(self) -> Dict[str, Tuple[Array, Array]]:
        return dict(self._ranges)

    def load_ranges(self, ranges: Dict[str, Tuple[Array, Array]]) -> None:
        self._ranges = dict(ranges)
        self.mode = "apply"

    def use_int8_runtime(self) -> None:
        """Switch a calibrated context to the hardware int8 path.

        In 'int8' mode the fake-quant sites become identity — real W8A8
        quantizes the two matmul operands, not every intermediate — and
        ``act_qparams`` serves the static input ranges to linear_apply.
        All (s, z) pairs are materialized to python floats HERE, outside
        any trace."""
        assert self._ranges or self.mode == "apply", (
            "use_int8_runtime needs finalized calibration ranges")
        spec = self.qconfig.act_spec()
        self._act_qp = {}
        for name, (lo, hi) in self._ranges.items():
            if name.endswith("#w"):     # weight ranges: not activation sites
                continue
            s, z = scale_zero_point(lo, hi, spec)
            self._act_qp[name] = (float(s), float(z))
        self.mode = "int8"

    def act_qparams(self, name: str) -> Optional[Tuple[float, float]]:
        """Static (scale, zero_point) for an activation site, as python
        floats (jit-safe closure constants). None if the site was not seen
        during calibration or is skipped — callers fall back to dynamic
        ranging inside the kernel."""
        if self.qconfig is None or self.qconfig.skipped(name):
            return None
        return self._act_qp.get(name)

    # -- the two quantization sites --------------------------------------
    def act(self, name: str, x: Array) -> Array:
        if (self.mode in ("off", "int8") or self.qconfig is None
                or self.qconfig.skipped(name)):
            return x
        spec = self.qconfig.act_spec()
        if self.mode == "collect":
            self._estimator_for(name, spec, self.qconfig.act_estimator).update(x)
            return x
        if name not in self._ranges:   # site unseen during calibration
            return x
        lo, hi = self._ranges[name]
        s, z = scale_zero_point(lo, hi, spec)
        return fake_quant(x, s, z, spec)

    def weight(self, name: str, w: Array) -> Array:
        if (self.mode in ("off", "int8") or self.qconfig is None
                or self.qconfig.skipped(name)):
            return w
        spec = self.qconfig.weight_spec(w.ndim)
        wname = name + "#w"
        if self.mode == "collect":
            self._estimator_for(wname, spec, self.qconfig.weight_estimator).update(w)
            return w
        if wname not in self._ranges:
            # Weights are static — derive the range on the fly (min-max).
            lo, hi = jnp.min(w), jnp.max(w)
        else:
            lo, hi = self._ranges[wname]
        s, z = scale_zero_point(lo, hi, spec)
        return fake_quant(w, s, z, spec)


NO_QUANT = QuantContext(None, "off")
