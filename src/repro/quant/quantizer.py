"""Uniform affine quantization simulation (paper Section 2, Eq. 1).

    q(x; s, z, b) = s * (clip(round(x / s) + z, 0, 2^b - 1) - z)

Asymmetric (affine) quantization for activations, symmetric for weights —
the paper's W8A8 PTQ setup (Section 5, "Quantization setup"). Fake-quant is
simulated in floating point per Jacob et al. [26], with a straight-through
estimator so QAT-style fine-tuning also works.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of one quantizer."""

    bits: int = 8
    symmetric: bool = False       # True for weights, False for activations
    per_channel_axis: Optional[int] = None  # None = per-tensor (paper default)

    @property
    def n_levels(self) -> int:
        return 2 ** self.bits


def scale_zero_point(
    x_min: Array, x_max: Array, spec: QuantSpec, eps: float = 1e-8
) -> Tuple[Array, Array]:
    """Scale s and zero-point z from a (min, max) range.

    Symmetric: grid symmetric around 0, z = 2^(b-1) (mid level) so that the
    dequantized grid is s * [-2^(b-1), 2^(b-1)-1].
    Asymmetric: classic uniform affine with the range nudged to include 0.
    """
    x_min = jnp.asarray(x_min, jnp.float32)
    x_max = jnp.asarray(x_max, jnp.float32)
    n = spec.n_levels
    if spec.symmetric:
        amax = jnp.maximum(jnp.abs(x_min), jnp.abs(x_max))
        s = jnp.maximum(amax / (n / 2 - 1), eps)
        z = jnp.full_like(s, n // 2)
    else:
        x_min = jnp.minimum(x_min, 0.0)   # range must include zero
        x_max = jnp.maximum(x_max, 0.0)
        s = jnp.maximum((x_max - x_min) / (n - 1), eps)
        z = jnp.round(-x_min / s)
        z = jnp.clip(z, 0, n - 1)
    return s, z


def quantize(x: Array, s: Array, z: Array, spec: QuantSpec) -> Array:
    """x -> integer grid (stored as int32) via Eq. 1 (without dequant)."""
    if spec.per_channel_axis is not None:
        shape = [1] * x.ndim
        shape[spec.per_channel_axis] = -1
        s = s.reshape(shape)
        z = z.reshape(shape)
    q = jnp.round(x / s) + z
    return jnp.clip(q, 0, spec.n_levels - 1).astype(jnp.int32)


def dequantize(q: Array, s: Array, z: Array, spec: QuantSpec) -> Array:
    if spec.per_channel_axis is not None:
        shape = [1] * q.ndim
        shape[spec.per_channel_axis] = -1
        s = s.reshape(shape)
        z = z.reshape(shape)
    return (s * (q.astype(jnp.float32) - z)).astype(jnp.float32)


def fake_quant(x: Array, s: Array, z: Array, spec: QuantSpec) -> Array:
    """Simulated quantization q(x) (Eq. 1), with a straight-through gradient.

    forward:  dequantize(quantize(x))
    backward: identity inside the representable range (STE); values that
    were clipped get zero gradient (matches integer-hardware behaviour and
    the paper's clipping-stops-gradients insight).
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if spec.per_channel_axis is not None:
        shape = [1] * x.ndim
        shape[spec.per_channel_axis] = -1
        s_b = s.reshape(shape)
        z_b = z.reshape(shape)
    else:
        s_b, z_b = s, z
    lo = s_b * (0.0 - z_b)
    hi = s_b * (spec.n_levels - 1 - z_b)
    x_clip = jnp.clip(xf, lo, hi)                      # STE passes grad here
    # Quant-dequant of the clipped value; stop_gradient on the rounding
    # residual gives the straight-through estimator.
    qd = s_b * (jnp.clip(jnp.round(x_clip / s_b + z_b), 0, spec.n_levels - 1) - z_b)
    out = x_clip + jax.lax.stop_gradient(qd - x_clip)
    return out.astype(dtype)


def quantization_error(x: Array, s: Array, z: Array, spec: QuantSpec) -> Array:
    """Mean squared error of fake-quantizing x — used by the MSE estimator."""
    return jnp.mean((x.astype(jnp.float32) - fake_quant(x, s, z, spec).astype(jnp.float32)) ** 2)
