"""Quantization range estimators (paper Appendix C.4).

  - ``MinMaxEstimator``        : running exact min/max
  - ``RunningMinMaxEstimator`` : EMA of batch min/max, momentum 0.9 over 16
                                 calibration batches (paper's main setting)
  - ``PercentileEstimator``    : 99.99% / 99.999% percentiles (best for OPT)
  - ``MSEEstimator``           : grid-search the clipping range minimizing
                                 quantization MSE (recommended for <8-bit,
                                 paper App. B.7 / Banner et al.)

All estimators consume activation (or weight) tensors batch-by-batch during
calibration and produce a final (min, max) range, from which
``quantizer.scale_zero_point`` derives (s, z).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.quantizer import QuantSpec, quantization_error, scale_zero_point

Array = jax.Array


class RangeEstimator:
    """Base: stateful accumulator over calibration batches."""

    def update(self, x: Array) -> None:
        raise NotImplementedError

    def finalize(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def reset(self) -> None:
        raise NotImplementedError


class MinMaxEstimator(RangeEstimator):
    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self._min: Optional[float] = None
        self._max: Optional[float] = None

    def update(self, x: Array) -> None:
        lo = float(jnp.min(x))
        hi = float(jnp.max(x))
        self._min = lo if self._min is None else min(self._min, lo)
        self._max = hi if self._max is None else max(self._max, hi)

    def finalize(self):
        assert self._min is not None, "estimator saw no data"
        return jnp.float32(self._min), jnp.float32(self._max)


class RunningMinMaxEstimator(RangeEstimator):
    """Exponential moving average of per-batch min/max (Krishnamoorthi [32])."""

    def __init__(self, momentum: float = 0.9) -> None:
        self.momentum = momentum
        self.reset()

    def reset(self) -> None:
        self._min: Optional[float] = None
        self._max: Optional[float] = None

    def update(self, x: Array) -> None:
        lo = float(jnp.min(x))
        hi = float(jnp.max(x))
        if self._min is None:
            self._min, self._max = lo, hi
        else:
            m = self.momentum
            self._min = m * self._min + (1 - m) * lo
            self._max = m * self._max + (1 - m) * hi

    def finalize(self):
        assert self._min is not None, "estimator saw no data"
        return jnp.float32(self._min), jnp.float32(self._max)


class PercentileEstimator(RangeEstimator):
    """min/max replaced by (1-p)/p percentiles of the pooled sample.

    The paper found 99.999% percentiles give the lowest W8A8 perplexity for
    OPT. We keep a bounded reservoir per batch to stay memory-safe.
    """

    def __init__(self, percentile: float = 99.999, reservoir: int = 1 << 20) -> None:
        assert 50.0 < percentile < 100.0
        self.percentile = percentile
        self.reservoir = reservoir
        self.reset()

    def reset(self) -> None:
        self._samples: list[np.ndarray] = []
        self._rng = np.random.default_rng(0)

    def update(self, x: Array) -> None:
        flat = np.asarray(x, dtype=np.float32).reshape(-1)
        if flat.size > self.reservoir:
            flat = self._rng.choice(flat, size=self.reservoir, replace=False)
        self._samples.append(flat)

    def finalize(self):
        assert self._samples, "estimator saw no data"
        pooled = np.concatenate(self._samples)
        lo = np.percentile(pooled, 100.0 - self.percentile)
        hi = np.percentile(pooled, self.percentile)
        return jnp.float32(lo), jnp.float32(hi)


class MSEEstimator(RangeEstimator):
    """Clipping-range grid search minimizing fake-quant MSE.

    Candidates are the observed min-max range scaled by factors in
    (0, 1]; the factor minimizing sum of per-batch quantization MSE wins.
    Used for weights (OPT) and all <8-bit settings (paper App. B.7).
    """

    def __init__(self, spec: QuantSpec, n_candidates: int = 40) -> None:
        self.spec = spec
        self.n_candidates = n_candidates
        self.reset()

    def reset(self) -> None:
        self._batches: list[jnp.ndarray] = []
        self._min: Optional[float] = None
        self._max: Optional[float] = None

    def update(self, x: Array) -> None:
        lo = float(jnp.min(x))
        hi = float(jnp.max(x))
        self._min = lo if self._min is None else min(self._min, lo)
        self._max = hi if self._max is None else max(self._max, hi)
        flat = jnp.ravel(jnp.asarray(x, jnp.float32))
        if flat.size > (1 << 18):
            idx = np.random.default_rng(len(self._batches)).choice(
                flat.size, size=1 << 18, replace=False
            )
            flat = flat[jnp.asarray(idx)]
        self._batches.append(flat)

    def finalize(self):
        assert self._batches, "estimator saw no data"
        pooled = jnp.concatenate(self._batches)
        # independent grid over lo/hi clipping factors: outliers are often
        # one-sided (paper Fig. 1), so scaling both ends together would
        # sacrifice the clean side of the distribution
        n = max(int(self.n_candidates ** 0.5), 6)
        factors = np.linspace(1.0 / n, 1.0, n)
        best = (None, np.inf)
        for f_lo in factors:
            for f_hi in factors:
                lo = jnp.float32(self._min * f_lo)
                hi = jnp.float32(self._max * f_hi)
                s, z = scale_zero_point(lo, hi, self.spec)
                err = float(quantization_error(pooled, s, z, self.spec))
                if err < best[1]:
                    best = ((lo, hi), err)
        return best[0]


def make_estimator(kind: str, spec: QuantSpec, **kw) -> RangeEstimator:
    if kind == "minmax":
        return MinMaxEstimator()
    if kind == "running_minmax":
        return RunningMinMaxEstimator(**kw)
    if kind == "percentile":
        return PercentileEstimator(**kw)
    if kind == "mse":
        return MSEEstimator(spec, **kw)
    raise ValueError(f"unknown range estimator {kind!r}")
