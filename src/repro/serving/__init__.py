from repro.serving.decode import (
    GenerateConfig,
    decode_one,
    generate,
    prefill,
    sample_logits,
    sample_rows,
    sample_token_at,
)

__all__ = ["GenerateConfig", "decode_one", "generate", "prefill",
           "sample_logits", "sample_rows", "sample_token_at"]
from repro.serving.scheduler import (  # noqa: E402
    BlockAllocator,
    ContinuousBatcher,
    Request,
)

__all__ += ["BlockAllocator", "ContinuousBatcher", "Request"]
