from repro.serving.decode import (
    GenerateConfig,
    decode_one,
    generate,
    prefill,
    sample_logits,
)

__all__ = ["GenerateConfig", "decode_one", "generate", "prefill",
           "sample_logits"]
from repro.serving.scheduler import (  # noqa: E402
    BlockAllocator,
    ContinuousBatcher,
    Request,
)

__all__ += ["BlockAllocator", "ContinuousBatcher", "Request"]
