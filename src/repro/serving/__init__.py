from repro.serving.decode import (
    GenerateConfig,
    decode_one,
    generate,
    prefill,
    sample_logits,
)

__all__ = ["GenerateConfig", "decode_one", "generate", "prefill",
           "sample_logits"]
from repro.serving.scheduler import ContinuousBatcher, Request  # noqa: E402

__all__ += ["ContinuousBatcher", "Request"]
