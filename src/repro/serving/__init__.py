from repro.serving.decode import (
    GenerateConfig,
    chunked_prefill,
    decode_one,
    generate,
    prefill,
    sample_logits,
    sample_rows,
    sample_token_at,
    step_rows,
)

__all__ = ["GenerateConfig", "chunked_prefill", "decode_one", "generate",
           "prefill", "sample_logits", "sample_rows", "sample_token_at",
           "step_rows"]
from repro.serving.scheduler import (  # noqa: E402
    BlockAllocator,
    ContinuousBatcher,
    PrefillState,
    Request,
)

__all__ += ["BlockAllocator", "ContinuousBatcher", "PrefillState", "Request"]
