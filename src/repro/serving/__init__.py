from repro.serving.decode import (
    GenerateConfig,
    chunked_prefill,
    decode_one,
    generate,
    prefill,
    sample_logits,
    sample_rows,
    sample_rows_all,
    sample_token_at,
    make_mixed_step,
    make_spec_step,
    step_rows,
    step_rows_full,
)

__all__ = ["GenerateConfig", "chunked_prefill", "decode_one", "generate",
           "prefill", "sample_logits", "sample_rows", "sample_rows_all",
           "sample_token_at", "make_mixed_step", "make_spec_step",
           "step_rows", "step_rows_full"]
from repro.serving.speculate import NGramDrafter, SpecConfig  # noqa: E402

__all__ += ["NGramDrafter", "SpecConfig"]
from repro.serving.scheduler import (  # noqa: E402
    AllocatorAuditError,
    BlockAllocator,
    ContinuousBatcher,
    PrefillState,
    Request,
    SwappedState,
)

__all__ += ["AllocatorAuditError", "BlockAllocator", "ContinuousBatcher",
            "PrefillState", "Request", "SwappedState"]
from repro.serving.workload import (  # noqa: E402
    DEFAULT_TIERS,
    TickCostModel,
    TierSpec,
    TraceEntry,
    WorkloadConfig,
    WorkloadReport,
    generate_trace,
    run_workload,
)

__all__ += ["DEFAULT_TIERS", "TickCostModel", "TierSpec", "TraceEntry",
            "WorkloadConfig", "WorkloadReport", "generate_trace",
            "run_workload"]
from repro.serving.chaos import (  # noqa: E402
    ChaosHarness,
    FaultPlan,
    FaultyAllocator,
)

__all__ += ["ChaosHarness", "FaultPlan", "FaultyAllocator"]
from repro.serving.prefix_cache import PrefixCache  # noqa: E402

__all__ += ["PrefixCache"]
