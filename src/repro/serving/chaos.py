"""Deterministic fault injection + invariant auditing for the engine.

The scheduler's failure handling (transient-fault stalling, bounded
swap-in retry, priority-ordered shedding) is worthless if it only runs on
the happy path. This module makes faults *reproducible*: a ``FaultPlan``
is a seeded schedule of misbehaviour — allocation failures at chosen
ticks, spurious preemption storms, admission floods of junk requests,
swap-in denial windows — and ``ChaosHarness`` replays it against a live
``ContinuousBatcher``, running the full block-accounting audit after
every step. The contract under chaos:

  * **never a crash** — every injected fault is absorbed by policy
    (retry, stall, degrade to recompute, or shed in priority order);
  * **never a corrupted row** — surviving requests produce exactly the
    tokens an unperturbed engine would (position-keyed sampling +
    quantize-on-write make this checkable bitwise);
  * **never a leaked block** — ``batcher.audit()`` passes after every
    tick: each block is exactly one of free / owned-by-a-live-row, block
    tables mirror slot state, swap-byte accounting balances.

Fault taxonomy (matching the scheduler's degradation order):

  ``alloc_fail``      transient: allocator refuses although blocks exist.
                      Engine must stall that row and retry next tick —
                      *not* preempt (the pool isn't actually full) — and
                      only shed (lowest priority first) if the fault
                      persists past its streak budget.
  ``preempt_storm``   spurious preemptions of running rows. Victims must
                      resume (swap or recompute) token-exact.
  ``flood``           bursts of junk admissions at low priority. Must not
                      starve higher tiers or corrupt accounting.
  ``swap_deny``       swap-in refusals. Engine retries a bounded number
                      of times then degrades to recompute-resume.
  ``prefix_storm``    bursts of near-identical prompts (a shared system
                      prefix + tiny random tails) followed by cancel
                      bursts of roughly half the storm one tick later —
                      the hostile pattern for the prefix cache: heavy
                      trie sharing, refcounts spiking and collapsing,
                      copy-on-write divergence and LRU eviction all
                      racing the other faults. The refcount audit
                      ("every block's refcount equals its owner count
                      across tables + trie + sampling groups") must hold
                      after every tick.

Run the seeded smoke (also wired into CI's fast tier)::

    PYTHONPATH=src python -m repro.serving.chaos --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.serving.scheduler import ContinuousBatcher, Request


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, fully explicit schedule of faults over ``ticks`` engine
    steps. Instances are plain data — printable, diffable, replayable."""
    seed: int
    ticks: int
    alloc_fail: frozenset = frozenset()     # ticks where alloc is denied
    preempt_storm: Tuple[Tuple[int, int], ...] = ()   # (tick, n_victims)
    flood: Tuple[Tuple[int, int], ...] = ()           # (tick, n_junk)
    swap_deny: frozenset = frozenset()      # ticks where swap-in is denied
    # (tick, n) bursts of near-identical prompts; ~half of each burst is
    # cancelled one tick later (defaults empty so pre-existing plans are
    # byte-identical to before this field existed)
    prefix_storm: Tuple[Tuple[int, int], ...] = ()

    @staticmethod
    def random(seed: int, ticks: int = 40,
               p_alloc: float = 0.15, p_storm: float = 0.10,
               p_flood: float = 0.08, p_deny: float = 0.15,
               p_prefix: float = 0.0) -> "FaultPlan":
        """Draw a plan from a seeded RNG. Distinct seeds give distinct
        plans; the same seed always gives the same plan (and plans drawn
        with ``p_prefix=0`` are identical to pre-prefix-storm plans: the
        extra draw only happens when the probability is nonzero)."""
        rng = np.random.default_rng(seed)
        alloc: Set[int] = set()
        storms: List[Tuple[int, int]] = []
        floods: List[Tuple[int, int]] = []
        deny: Set[int] = set()
        prefix: List[Tuple[int, int]] = []
        for t in range(ticks):
            r = rng.random(4)
            if r[0] < p_alloc:
                # faults arrive in short bursts, like a real flaky resource
                for d in range(int(rng.integers(1, 4))):
                    alloc.add(t + d)
            if r[1] < p_storm:
                storms.append((t, int(rng.integers(1, 3))))
            if r[2] < p_flood:
                floods.append((t, int(rng.integers(1, 4))))
            if r[3] < p_deny:
                deny.add(t)
            if p_prefix > 0 and float(rng.random()) < p_prefix:
                prefix.append((t, int(rng.integers(2, 6))))
        return FaultPlan(seed=seed, ticks=ticks,
                         alloc_fail=frozenset(alloc),
                         preempt_storm=tuple(storms),
                         flood=tuple(floods),
                         swap_deny=frozenset(deny),
                         prefix_storm=tuple(prefix))


class FaultyAllocator:
    """Wraps a ``BlockAllocator``; on fault ticks every ``alloc`` is
    denied (returns None) while the blocks remain genuinely available —
    exactly the "spurious failure" the scheduler must treat as transient.
    All other methods delegate, so the audit sees the real free list."""

    def __init__(self, inner):
        self.inner = inner
        self.failing = False
        self.denied = 0

    @property
    def num_blocks(self) -> int:
        return self.inner.num_blocks

    @property
    def available(self) -> int:
        return self.inner.available

    def alloc(self, n: int):
        if self.failing and n > 0:
            self.denied += 1
            return None
        return self.inner.alloc(n)

    def free(self, blocks) -> None:
        self.inner.free(blocks)

    def acquire(self, blocks) -> None:
        # reference bumps on live blocks never fail: only fresh
        # allocation is the flaky resource being modeled
        self.inner.acquire(blocks)

    def release(self, blocks) -> None:
        self.inner.release(blocks)

    def refcount(self, block: int) -> int:
        return self.inner.refcount(block)

    def free_list(self):
        return self.inner.free_list()


class ChaosHarness:
    """Replays a ``FaultPlan`` against a batcher: per tick, arms the
    faulty allocator, fires preemption storms / floods due this tick,
    steps the engine, then runs the full allocator audit. Any crash or
    audit failure propagates — the test contract is that none occurs."""

    JUNK_UID0 = 1_000_000            # flood uids, outside any trace

    def __init__(self, batcher: ContinuousBatcher, plan: FaultPlan,
                 vocab: int = 64):
        self.b = batcher
        self.plan = plan
        self.vocab = vocab
        self.rng = np.random.default_rng(plan.seed ^ 0x5EED)
        self.tick = 0
        self._junk = ChaosHarness.JUNK_UID0
        self.events: List[str] = []
        if batcher.paged:
            batcher.allocator = FaultyAllocator(batcher.allocator)
        batcher._swap_in_gate = \
            lambda req: self.tick not in self.plan.swap_deny
        self._storms: Dict[int, int] = dict(plan.preempt_storm)
        self._floods: Dict[int, int] = dict(plan.flood)
        self._prefix_storms: Dict[int, int] = dict(plan.prefix_storm)
        # one hostile "system prompt" per harness: long enough to span
        # several blocks so storm prompts share real trie state
        plen = 3 * batcher.block_size if batcher.paged else 12
        plen = min(plen, max(1, batcher.L // 2))
        self._prefix = self.rng.integers(4, vocab, size=plen) \
            .astype(np.int32)
        self._cancel_next: List[int] = []   # storm uids due for cancelling

    def _storm(self, n: int) -> None:
        live = [i for i, s in enumerate(self.b.slots) if s.req is not None]
        self.rng.shuffle(live)
        for i in live[:n]:
            if self.b.slots[i].req is None:     # freed by an earlier victim
                continue
            self.events.append(f"t{self.tick} preempt slot{i} "
                               f"uid{self.b.slots[i].req.uid}")
            self.b.preempt_slot(i)

    def _prefix_storm_burst(self, n: int) -> None:
        """Submit ``n`` near-identical prompts (shared prefix + a 0-3
        token random tail, occasionally n>1 parallel sampling) and queue
        roughly half of them for a cancel burst next tick — admission
        sharing, CoW divergence, refcount churn and mid-flight teardown
        all at once."""
        burst: List[int] = []
        for _ in range(n):
            tail_len = int(self.rng.integers(0, 4))
            tail = self.rng.integers(4, self.vocab, size=tail_len)
            prompt = np.concatenate([self._prefix,
                                     tail.astype(np.int32)])
            fanout = int(self.rng.integers(1, 3))    # sometimes n=2
            self.b.submit(Request(uid=self._junk,
                                  prompt=prompt,
                                  max_new_tokens=int(self.rng.integers(1, 5)),
                                  priority=int(self.rng.integers(0, 2)),
                                  n=fanout))
            self.events.append(f"t{self.tick} prefix_storm uid{self._junk} "
                               f"n={fanout}")
            burst.append(self._junk)
            self._junk += 1
        self.rng.shuffle(burst)
        self._cancel_next.extend(burst[:len(burst) // 2])

    def _flood(self, n: int) -> None:
        for _ in range(n):
            plen = int(self.rng.integers(1, 9))
            prompt = self.rng.integers(4, self.vocab, size=plen)
            self.b.submit(Request(uid=self._junk,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=int(self.rng.integers(1, 5)),
                                  priority=-1))
            self.events.append(f"t{self.tick} flood uid{self._junk}")
            self._junk += 1

    def step(self, now: Optional[float] = None) -> None:
        t = self.tick
        if self.b.paged:
            self.b.allocator.failing = t in self.plan.alloc_fail
        if self._cancel_next:
            for uid in self._cancel_next:
                if self.b.cancel(uid):
                    self.events.append(f"t{t} cancel uid{uid}")
            self._cancel_next = []
        if t in self._storms:
            self._storm(self._storms[t])
        if t in self._floods:
            self._flood(self._floods[t])
        if t in self._prefix_storms:
            self._prefix_storm_burst(self._prefix_storms[t])
        self.b.step(now=now)
        self.b.audit()
        self.tick += 1

    def run(self, drain_ticks: int = 400) -> None:
        """Run the plan's ticks, then disarm all faults and drain."""
        for _ in range(self.plan.ticks):
            self.step()
        if self.b.paged:
            self.b.allocator.failing = False
        self.b._swap_in_gate = None
        for _ in range(drain_ticks):
            if not self.b.queue and \
                    all(s.req is None for s in self.b.slots):
                return
            self.b.step()
            self.b.audit()
        raise RuntimeError("engine failed to drain after chaos plan "
                           f"seed={self.plan.seed}")


def _smoke() -> int:
    """Six seeded plans against a tiny paged int8-KV engine with the
    prefix cache live (5 general fault plans + 1 prefix-storm plan);
    exits nonzero on any crash, refcount-audit violation, or failed
    drain."""
    import jax
    from repro.models import model_init
    from repro.models.transformer import ModelConfig

    cfg = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64, pos="rope",
                      max_seq_len=64, scan_layers=False, remat=False,
                      mlp_kind="swiglu", norm="rmsnorm")
    params = model_init(jax.random.PRNGKey(0), cfg)
    plans = [FaultPlan.random(seed, ticks=30) for seed in range(5)]
    # the prefix-cache hostile plan: prompt bursts sharing a system
    # prefix + cancel bursts, on top of a light dose of the other faults
    plans.append(FaultPlan.random(5, ticks=30, p_alloc=0.10,
                                  p_storm=0.08, p_flood=0.05,
                                  p_deny=0.10, p_prefix=0.35))
    for seed, plan in enumerate(plans):
        b = ContinuousBatcher(
            params, cfg, batch_size=4, max_len=64, token_budget=48,
            paged=True, num_blocks=24, block_size=8, kv_int8=True,
            swap_break_even_tokens=16, on_pool_exhausted="shed",
            prefix_cache=True, debug_audit=True)
        rng = np.random.default_rng(1234 + seed)
        for uid in range(10):
            plen = int(rng.integers(2, 24))
            b.submit(Request(
                uid=uid,
                prompt=rng.integers(4, 64, size=plen).astype(np.int32),
                max_new_tokens=int(rng.integers(2, 9)),
                priority=int(rng.integers(0, 3))))
        h = ChaosHarness(b, plan)
        h.run()
        kind = "prefix-storm" if plan.prefix_storm else "general"
        print(f"plan seed={seed} ({kind}): done={len(b.done)} "
              f"failed={len(b.failed)} "
              f"denied_allocs={b.allocator.denied} "
              f"prefix_hits={b.prefix_cache.hits} "
              f"cow={b.cow_copies} evictions={b.prefix_cache.evictions} "
              f"events={len(h.events)} audit=clean")
        if plan.prefix_storm and b.prefix_cache.hits == 0:
            print("FAIL: prefix-storm plan produced no trie hits")
            return 1
    print("chaos smoke: 6 plans (incl. prefix-storm), zero crashes, "
          "zero refcount-audit violations")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run 5 seeded fault plans against a tiny engine")
    args = ap.parse_args()
    if args.smoke:
        return _smoke()
    ap.print_help()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
