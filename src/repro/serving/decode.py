"""Batched serving: prefill + greedy/temperature decode with a static KV
cache. ``generate`` drives (prefill_step, decode_step) — the same functions
the decode_* dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache, model_apply

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    eos_id: Optional[int] = None


def prefill(params, cfg: ModelConfig, tokens: Array, max_len: int):
    """Run the prompt through the model, building the KV cache.

    Returns (last_logits (B, vocab), cache, prompt_len)."""
    b, t = tokens.shape
    cache = init_cache(cfg, b, max_len)
    logits, aux = model_apply(params, cfg, {"tokens": tokens},
                              cache=cache, pos=0)
    return logits[:, -1, :], aux["cache"], t


def decode_one(params, cfg: ModelConfig, cache, tokens: Array, pos):
    logits, aux = model_apply(params, cfg, {"tokens": tokens},
                              cache=cache, pos=pos)
    return logits[:, -1, :], aux["cache"]


def generate(params, cfg: ModelConfig, prompt: Array, gen: GenerateConfig,
             key: Optional[Array] = None) -> Array:
    """Greedy/temperature sampling. prompt: (B, T) int32. Returns
    (B, T + max_new_tokens)."""
    b, t = prompt.shape
    max_len = t + gen.max_new_tokens
    last_logits, cache, pos = prefill(params, cfg, prompt, max_len)
    decode = jax.jit(decode_one, static_argnums=(1,))

    def sample(logits, k):
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / gen.temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = [prompt]
    cur = sample(last_logits, key)[:, None]
    for i in range(gen.max_new_tokens - 1):
        toks.append(cur)
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cfg, cache, cur, pos)
        pos = pos + 1
        cur = sample(logits, sub)[:, None]
    toks.append(cur)
    return jnp.concatenate(toks, axis=1)
