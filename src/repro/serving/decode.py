"""Fused serving decode: prefill + a fully-jitted token-generation loop.

``generate`` runs the whole decode as ONE compiled program — a
``lax.while_loop`` that samples (greedy / temperature / top-k), honors
``eos_id`` with a per-row finished mask (later positions are padded with
``pad_id``), and early-exits once every row is finished. There is no
per-token python dispatch; (prefill, decode) are the same functions the
decode_* dry-run cells lower, and ``decode_one`` accepts per-row positions
plus an active mask so the continuous batcher shares the exact same step.

The two per-row contracts the batcher builds on (both live in
``model_apply`` / ``core.attention``, documented here because this module is
their serving entry point):

  * ``pos`` / ``q_offset`` vectors — every position argument may be a shared
    scalar OR a per-row (B,) int32 vector. With a vector, row b's query
    block sits at absolute position ``pos[b]`` (RoPE angles, learned
    positional embeds and attention masks all index per row), which is what
    lets one fused step decode a batch whose rows are at unrelated
    positions.
  * masked scatter cache writes — with vector ``pos``, KV-cache updates are
    per-row scatters at ``pos[b]``; rows with ``active[b] == False`` have
    their write index redirected out of bounds and dropped (jax scatter
    ``mode="drop"``), so a dead or stalled row's cache is left bit-exact
    untouched without any save/restore double buffering.

``generate`` itself always uses the dense contiguous cache (a standalone
batch has no reuse to exploit); the paged block-pool cache is a scheduler
concern — see ``repro.serving.scheduler`` and ``docs/serving.md``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache, model_apply
from repro.quant.qconfig import NO_QUANT, QuantContext

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    top_k: Optional[int] = None    # sample only among the k best logits
    eos_id: Optional[int] = None   # a row stops after emitting this token
    pad_id: int = 0                # fills positions after EOS


def sample_logits(logits: Array, gen: GenerateConfig,
                  key: Optional[Array] = None) -> Array:
    """(B, vocab) logits -> (B,) int32 token ids."""
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("sample_logits needs a PRNG key when temperature > 0")
    if gen.top_k is not None and 0 < gen.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, gen.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits / gen.temperature).astype(jnp.int32)


def sample_token_at(logits: Array, gen: GenerateConfig, key: Array,
                    target_pos) -> Array:
    """(vocab,) logits -> () int32 token id for ONE row, keyed by the
    token's absolute position.

    The continuous batcher's sampling rule: the token that will sit at
    logical position p is drawn with ``fold_in(request_key, p)``. Keying by
    *position* rather than by draw order makes sampling a pure function of
    (request seed, position), so a preempted request recomputed from its
    prompt + generated-so-far resamples the identical continuation — the
    sampling analogue of the greedy recompute-resume guarantee."""
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jax.random.fold_in(key, jnp.asarray(target_pos, jnp.int32))
    return sample_logits(logits[None], gen, k)[0]


def sample_rows(logits: Array, gen: GenerateConfig, keys: Array,
                target_pos: Array) -> Array:
    """Per-row batched ``sample_token_at``: (B, vocab) logits, (B, 2)
    uint32 per-request keys, (B,) target positions -> (B,) int32 tokens.
    The fused-tick sampler of ``ContinuousBatcher``."""
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(lambda l, k, p: sample_token_at(l, gen, k, p))(
        logits, keys, target_pos)


def sample_rows_all(logits: Array, gen: GenerateConfig, keys: Array,
                    pos: Array) -> Array:
    """Every-position sampler for the speculative tick: (B, T, vocab)
    logits, (B, 2) uint32 keys, (B,) row start positions -> (B, T) int32.

    Entry ``[b, j]`` is the token plain decoding would place at absolute
    position ``pos[b] + j + 1``, sampled from ``logits[b, j]`` under the
    position-keyed rule (``fold_in(key_b, pos_b + j + 1)``; greedy is
    argmax). The verifier's accept test compares draft tokens against
    these entries, so speculation inherits bitwise equality with plain
    decoding from the same invariance that makes chunked prefill and
    recompute-resume exact. Padding positions (j >= the row's real token
    count) produce garbage entries the host never reads."""
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = logits.shape[1]
    tpos = pos[:, None] + 1 + jnp.arange(t, dtype=jnp.int32)[None, :]
    per_row = jax.vmap(lambda l, k, p: sample_token_at(l, gen, k, p),
                       in_axes=(0, None, 0))
    return jax.vmap(per_row)(logits, keys, tpos)


def prefill(params, cfg: ModelConfig, tokens: Array, max_len: int):
    """Run the prompt through the model, building the KV cache.

    Returns (last_logits (B, vocab), cache, prompt_len)."""
    b, t = tokens.shape
    cache = init_cache(cfg, b, max_len)
    logits, aux = model_apply(params, cfg, {"tokens": tokens},
                              cache=cache, pos=0)
    return logits[:, -1, :], aux["cache"], t


def _ring_chunk_cap(cfg: ModelConfig, max_len: int) -> Optional[int]:
    """Largest prefill chunk a ``local_attn`` ring admits (the batcher's
    ``ring_cap``): a chunk must fit the ring and its own writes must not
    collide inside it. None when no layer uses a ring cache."""
    kinds = tuple(cfg.pattern) + tuple(cfg.tail_pattern)
    if any(k == "local_attn" for k in kinds) and cfg.window:
        return min(max_len, cfg.window)
    return None


def chunked_prefill(params, cfg: ModelConfig, tokens: Array, max_len: int,
                    chunk: Optional[int] = None):
    """Stream the prompt through ``step_rows`` in uniform chunks — the
    batcher's chunked-prefill contract (per-row pos vectors + per-token
    active masks), usable standalone. Unlike one-shot ``prefill`` this
    works for ``local_attn`` prompts longer than the window: each chunk is
    capped at the ring so the pre-write ring read path sees a consistent
    window (see ``model_apply``).

    Returns (last_logits (B, vocab), cache, prompt_len)."""
    b, t = tokens.shape
    cap = _ring_chunk_cap(cfg, max_len)
    step = min(x for x in (chunk, cap, t) if x is not None and x > 0)
    cache = init_cache(cfg, b, max_len)
    last = None
    for off in range(0, t, step):
        c = min(step, t - off)
        pos = jnp.full((b,), off, jnp.int32)
        counts = jnp.full((b,), c, jnp.int32)
        last, cache = step_rows(params, cfg, cache,
                                tokens[:, off:off + c], pos, counts)
    return last, cache, t


def decode_one(params, cfg: ModelConfig, cache, tokens: Array, pos,
               active: Optional[Array] = None):
    """One decode step. ``pos`` is a shared scalar or per-row (B,) vector;
    ``active`` masks cache writes of dead rows (see model_apply)."""
    logits, aux = model_apply(params, cfg, {"tokens": tokens},
                              cache=cache, pos=pos, active=active)
    return logits[:, -1, :], aux["cache"]


def step_rows(params, cfg: ModelConfig, cache, tokens: Array, pos: Array,
              counts: Array, paged_live_width: Optional[int] = None,
              paged_live_widths: Optional[Array] = None,
              ctx: QuantContext = NO_QUANT):
    """Variable-Tq fused step: the token-budget scheduler's mixed
    prefill/decode forward.

    ``tokens`` (B, T) carries every row's contribution for this tick,
    left-aligned: a decode row holds 1 token, a prefill row holds a chunk
    of its prompt, an idle row holds padding. ``pos`` (B,) is each row's
    absolute start position and ``counts`` (B,) its number of REAL tokens
    (0 = idle); the derived per-token active mask drops every padding
    token's cache write (see ``model_apply``). Returns
    (last_logits (B, vocab), cache) where ``last_logits[b]`` is the logits
    at row b's LAST real token — the only position whose prediction the
    scheduler may consume (chunk-aware sampling: a non-final prefill chunk
    discards it, the final chunk samples the request's first token from
    it, a decode row samples its next token).

    ``ctx``: optional QuantContext in 'int8' mode — the W8A8 serving path.
    Its calibrated ranges are python-float closure constants, so the tick
    stays jit-safe; the context is captured, not traced."""
    logits, cache = step_rows_full(
        params, cfg, cache, tokens, pos, counts,
        paged_live_width=paged_live_width,
        paged_live_widths=paged_live_widths, ctx=ctx)
    counts = jnp.asarray(counts, jnp.int32)
    last = jnp.take_along_axis(
        logits, jnp.maximum(counts - 1, 0)[:, None, None], axis=1)[:, 0, :]
    return last, cache


def step_rows_full(params, cfg: ModelConfig, cache, tokens: Array,
                   pos: Array, counts: Array,
                   paged_live_width: Optional[int] = None,
                   paged_live_widths: Optional[Array] = None,
                   ctx: QuantContext = NO_QUANT):
    """``step_rows`` returning ALL positions' logits (B, T, vocab) — the
    speculative tick's forward, where EVERY fed position's prediction is
    consumed (position j's logits decide the fate of draft token j+1).
    Same masked-scatter write contract: padding tokens (j >= counts[b])
    write nothing; *rejected draft* tokens DO write, which is sound
    because every read path masks by logical position — see
    ``make_spec_step``."""
    b, t = tokens.shape
    counts = jnp.asarray(counts, jnp.int32)
    active = jnp.arange(t, dtype=jnp.int32)[None, :] < counts[:, None]
    logits, aux = model_apply(params, cfg, {"tokens": tokens}, ctx=ctx,
                              cache=cache, pos=pos, active=active,
                              paged_live_width=paged_live_width,
                              paged_live_widths=paged_live_widths)
    return logits, aux["cache"]


def make_mixed_step(cfg: ModelConfig, gen: GenerateConfig,
                    ctx: QuantContext = NO_QUANT):
    """Build the jitted fused engine tick ``ContinuousBatcher`` runs every
    step: one ``step_rows`` forward advancing every runnable row at its own
    position — decode rows by 1 token, prefill rows by a chunk; padding
    tokens' writes are dropped inside model_apply (masked per-token
    scatter) — followed by position-keyed sampling. ``live_width``
    (static) bounds the paged attention read to the allocated block-table
    prefix and ``live_widths`` masks each row's read at its own block
    count; ``keys`` are per-request PRNG keys — the sampled token at
    position p is ``fold_in(key, p)``, so recompute-resume (and swap
    resume) replay identical samples. ``ctx`` carries calibrated int8
    ranges as jit closure constants (the W8A8 tick)."""

    def _mixed_step(params, cache, tokens, pos, counts, keys,
                    live_width, live_widths):
        last, new_cache = step_rows(
            params, cfg, cache, tokens, pos, counts,
            paged_live_width=live_width, paged_live_widths=live_widths,
            ctx=ctx)
        nxt = sample_rows(last, gen, keys, pos + counts)
        return nxt, new_cache

    return jax.jit(_mixed_step, static_argnums=(6,))


def make_spec_step(cfg: ModelConfig, gen: GenerateConfig,
                   ctx: QuantContext = NO_QUANT):
    """Build the jitted SPECULATIVE engine tick: one ``step_rows_full``
    forward verifying up to k draft tokens per decode row in a single
    variable-Tq read, returning the full (B, T) target-token matrix
    instead of one token per row.

    A decode row feeds ``[last_token, d_1 .. d_k]`` at its position; the
    returned ``tgt[b, j]`` is what plain decoding would emit at position
    ``pos[b] + j + 1``, so the host accepts the longest prefix of drafts
    with ``d_j == tgt[b, j-1]`` and always banks the bonus token
    ``tgt[b, n_acc]`` — 1..k+1 tokens per tick, bitwise identical to the
    non-speculative stream (see ``sample_rows_all``). Prefill rows ride
    the same forward unchanged: a final chunk's first token is
    ``tgt[b, c-1]``, exactly what ``make_mixed_step`` would have sampled,
    so one program serves the whole mixed tick.

    Rejected drafts HAVE already scattered their K/V into the cache when
    verification happens (write and read are one fused program). That is
    sound for global-attn caches, dense or paged, fp or int8: (a) every
    read path masks keys by logical position (causal mask / live-width
    mask over positions <= q), so entries past a row's accepted position
    are causally invisible; (b) the row's next writes land at those same
    positions and overwrite the stale entries before its position
    advances past them; (c) KV bits (incl. int8 quantize-at-write) are
    pure functions of (token, position), so the overwrite equals what a
    non-speculative tick would have written. It is NOT sound for ring
    (``local_attn``) or recurrent state — a ring write at pos % window
    clobbers live in-window history and a recurrence has no position to
    mask — which is why the scheduler refuses ``spec=`` for those
    configs. ``live_width`` stays the static pow-2-bucketed argument and
    T is bucketed by the scheduler, so the speculative tick adds at most
    log2(k+1) extra specializations."""

    def _spec_step(params, cache, tokens, pos, counts, keys,
                   live_width, live_widths):
        logits, new_cache = step_rows_full(
            params, cfg, cache, tokens, pos, counts,
            paged_live_width=live_width, paged_live_widths=live_widths,
            ctx=ctx)
        tgt = sample_rows_all(logits, gen, keys, pos)
        return tgt, new_cache

    return jax.jit(_spec_step, static_argnums=(6,))


@partial(jax.jit, static_argnums=(1, 4))
def _decode_loop(params, cfg: ModelConfig, cache, last_logits,
                 gen: GenerateConfig, pos, key):
    """Jitted whole-loop decode: returns ((B, max_new_tokens) tokens, cache).

    Token 0 comes from the prefill logits; each loop iteration decodes then
    samples, so no forward pass is wasted on the final token. The finished
    mask makes rows emit ``pad_id`` after EOS and the loop exits early once
    every row is done (EOS/length masking)."""
    b = last_logits.shape[0]
    n = gen.max_new_tokens
    if n == 0:
        return jnp.zeros((b, 0), jnp.int32), cache
    key, sub = jax.random.split(key)
    tok = sample_logits(last_logits, gen, sub)
    finished = tok == gen.eos_id if gen.eos_id is not None \
        else jnp.zeros((b,), jnp.bool_)
    buf = jnp.full((b, n), gen.pad_id, jnp.int32).at[:, 0].set(tok)
    pos = jnp.asarray(pos, jnp.int32)

    def cond(state):
        i, _, _, finished, _, _ = state
        return (i < n) & ~jnp.all(finished)

    def body(state):
        i, key, tok, finished, cache, buf = state
        logits, cache = decode_one(params, cfg, cache, tok[:, None],
                                   pos + i - 1)
        key, sub = jax.random.split(key)
        nxt = sample_logits(logits, gen, sub)
        if gen.eos_id is not None:
            nxt = jnp.where(finished, gen.pad_id, nxt)
            finished = finished | (nxt == gen.eos_id)
        buf = buf.at[:, i].set(nxt)
        return (i + 1, key, nxt, finished, cache, buf)

    state = (jnp.asarray(1, jnp.int32), key, tok, finished, cache, buf)
    _, _, _, _, cache, buf = jax.lax.while_loop(cond, body, state)
    return buf, cache


def generate(params, cfg: ModelConfig, prompt: Array, gen: GenerateConfig,
             key: Optional[Array] = None,
             prefill_chunk: Optional[int] = None) -> Array:
    """Greedy/temperature/top-k sampling. prompt: (B, T) int32. Returns
    (B, T + max_new_tokens); rows that emit ``gen.eos_id`` keep it and are
    padded with ``gen.pad_id`` afterwards.

    Prompts that overflow a ``local_attn`` ring (T > window) are prefilled
    through the batcher's chunked path automatically; ``prefill_chunk``
    forces chunked prefill with the given chunk size (it is still capped
    at the ring)."""
    t = prompt.shape[1]
    max_len = t + gen.max_new_tokens
    cap = _ring_chunk_cap(cfg, max_len)
    if prefill_chunk is None and (cap is None or t <= cap):
        last_logits, cache, pos = prefill(params, cfg, prompt, max_len)
    else:
        last_logits, cache, pos = chunked_prefill(
            params, cfg, prompt, max_len, chunk=prefill_chunk)
    key = key if key is not None else jax.random.PRNGKey(0)
    new_tokens, _ = _decode_loop(params, cfg, cache, last_logits, gen, pos, key)
    return jnp.concatenate([prompt, new_tokens], axis=1)
