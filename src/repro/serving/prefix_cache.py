"""Prefix cache: a token-ids-keyed trie over refcounted KV pool blocks.

At fleet scale most prompts share a system prefix, so a per-request block
pool re-stores (and re-prefills) the same KV content thousands of times.
This module is the host-side half of prefix *sharing*: a trie whose edges
are tuples of ``block_size`` token ids and whose nodes each pin ONE
physical pool block holding exactly that block's KV content. Admission
walks the trie with the arriving feed (``PrefixCache.match``) and maps
the longest cached prefix straight onto the existing physical blocks —
the row acquires a reference per block, its block table points at them,
and chunked prefill starts after the shared span. Completion publishes
the row's full prompt blocks back (``insert``), deduplicating against
nodes that already exist.

Why this is correct, not just fast:

  * a physical block id is valid for EVERY layer's pool — the scheduler
    keeps ONE host block table broadcast into all layers — so one trie
    node per block suffices;
  * KV bits are a pure function of (token value, logical position): the
    engine's chunk-size/slot/preemption invariance is already bitwise,
    and int8 KV quantizes each token exactly once at write with a
    per-token scale slot (``quant.kv_cache``), so a block written by one
    request reads bit-identically for any other request whose feed
    starts with the same tokens;
  * only FULL prompt blocks are cached. A partial tail block would keep
    receiving its first owner's later writes, so its content is not a
    function of the key. Full blocks under a shared prefix are write-once
    — matched rows start writing strictly after the span, which is why
    the scheduler's copy-on-write only ever fires for sampling-group
    tail sharing, never for trie hits;
  * a match is capped so at least one feed token remains to prefill:
    the request's first sampled token needs the logits of its last
    prompt token, which only a forward over that token produces.

Ownership: the trie holds exactly one allocator reference per node
(acquired at insert, released at evict), so the scheduler audit's
invariant — every block's refcount equals its owner count across slot
tables + trie + sampling groups — extends naturally. Under pool pressure
the scheduler evicts LRU nodes whose block has no other owner
(``evict``); nodes whose block a live row still references are skipped
(evicting them would free nothing) and children are always evicted
before their parent, so the trie never dangles. The cache can therefore
delay an allocation by at most one eviction sweep — it never *blocks*
admission.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class _Node:
    """One cached block: ``key`` is the tuple of ``block_size`` token ids
    this block holds, ``block`` the physical pool id (one allocator ref),
    ``last_use`` an LRU clock stamped by every match/insert that touches
    the node."""

    __slots__ = ("key", "block", "children", "parent", "last_use")

    def __init__(self, key: Optional[Tuple[int, ...]], block: int,
                 parent: Optional["_Node"]) -> None:
        self.key = key
        self.block = block
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_use = 0


class PrefixCache:
    """Block-granular prefix trie over a refcounted ``BlockAllocator``.

    The allocator is shared with the scheduler; the trie participates in
    block ownership exactly like a slot row does (one ref per node).
    ``hits``/``misses``/``tokens_reused``/``evictions`` are cumulative
    counters for observability and benchmarks."""

    def __init__(self, block_size: int, allocator) -> None:
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.block_size = block_size
        self.allocator = allocator
        self._root = _Node(None, -1, None)
        self._clock = 0
        self._count = 0
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0
        self.evictions = 0

    def __len__(self) -> int:
        return self._count

    # ------------------------------------------------------------------
    def match(self, tokens) -> List[int]:
        """Longest cached block-aligned prefix of ``tokens``, as physical
        block ids in order. Capped at ``(len(tokens) - 1) // block_size``
        blocks so >= 1 token always remains for the caller to prefill
        (the first sampled token needs the last feed token's logits).
        Touching a path refreshes its LRU stamps root-to-leaf. The caller
        must acquire its own references on the returned blocks before the
        next eviction can run."""
        bs = self.block_size
        max_blocks = max(0, (len(tokens) - 1) // bs)
        self._clock += 1
        node = self._root
        out: List[int] = []
        for j in range(max_blocks):
            key = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = self._clock
            out.append(child.block)
            node = child
        if out:
            self.hits += 1
            self.tokens_reused += len(out) * bs
        else:
            self.misses += 1
        return out

    def insert(self, tokens, blocks: List[int]) -> int:
        """Publish ``tokens``' full blocks into the trie, backed by the
        caller's physical ``blocks`` (parallel, block-aligned, block ``j``
        holding ``tokens[j*bs:(j+1)*bs]``). Existing nodes are kept — two
        concurrent cold prefills of the same prompt dedupe onto whichever
        published first; the loser's blocks simply stay private to its
        row. Each NEW node acquires one allocator reference. Returns the
        number of nodes added."""
        bs = self.block_size
        n_full = min(len(tokens) // bs, len(blocks))
        self._clock += 1
        node = self._root
        added = 0
        for j in range(n_full):
            key = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(blocks[j]), node)
                self.allocator.acquire([child.block])
                node.children[key] = child
                self._count += 1
                added += 1
            child.last_use = self._clock
            node = child
        return added

    # ------------------------------------------------------------------
    def _evictable_leaves(self) -> List[_Node]:
        """Leaves whose block the trie is the SOLE owner of (refcount 1):
        evicting anything else frees no memory, and evicting a non-leaf
        would dangle its children."""
        out: List[_Node] = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                if ch.children:
                    stack.append(ch)
                elif self.allocator.refcount(ch.block) == 1:
                    out.append(ch)
        return out

    def evictable(self) -> int:
        """How many blocks eviction could free right now. Live ownership
        is prefix-closed (a row matching a path holds refs on the whole
        path), so every sole-owner node is reachable leaf-upward and the
        count is simply the number of refcount-1 nodes."""
        n = 0
        stack = [self._root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                stack.append(ch)
                if self.allocator.refcount(ch.block) == 1:
                    n += 1
        return n

    def evict(self, n: int) -> int:
        """Free up to ``n`` blocks, least-recently-used sole-owner leaves
        first (a parent becomes a leaf once its children are gone, so a
        cold chain drains tail-to-root). Returns how many were freed."""
        freed = 0
        while freed < n:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_use)
            del victim.parent.children[victim.key]
            self.allocator.release([victim.block])
            self._count -= 1
            self.evictions += 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Evict everything evictable (tests, shutdown)."""
        return self.evict(self._count)

    # ------------------------------------------------------------------
    def cached_blocks(self) -> List[int]:
        """All block ids the trie currently owns (audit surface)."""
        out: List[int] = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                out.append(ch.block)
                stack.append(ch)
        return out
