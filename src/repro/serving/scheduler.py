"""Continuous-batching request scheduler for the decode path.

Real serving stacks (vLLM/JetStream-style) keep the decode batch full by
slotting new requests into finished sequences' cache rows instead of
waiting for the whole batch to drain. This is the jax-native equivalent:

  * a fixed-shape slot pool (batch B, max_len L) holds the KV cache;
  * each step decodes every active slot (one fused decode_step);
  * finished slots (EOS or length budget) are refilled from the queue by
    running a per-slot prefill into the shared cache row.

Slot bookkeeping is host-side python (cheap, O(B) per step); all tensor
work stays jitted with static shapes — the pattern that scales to the
pod-sharded cache (slots = batch rows, already sharded over dp).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, init_cache, model_apply

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 32
    # filled by the scheduler
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                     # next cache position
    generated: List[int] = dataclasses.field(default_factory=list)


class ContinuousBatcher:
    """Slot-pool scheduler over a shared static KV cache."""

    def __init__(self, params, cfg: ModelConfig, batch_size: int,
                 max_len: int, eos_id: Optional[int] = None) -> None:
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.L = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, batch_size, max_len)
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: List[Request] = []
        self.done: List[Request] = []

        def _decode(params, cache, tokens, pos_vec):
            # per-slot positions: run with the max pos and mask via causal
            # offsets is incorrect for mixed positions, so decode uses a
            # shared position per step; slots therefore decode in lockstep
            # cohorts (same pos) — we group by pos below.
            logits, aux = model_apply(params, cfg, {"tokens": tokens},
                                      cache=cache, pos=pos_vec)
            return jnp.argmax(logits[:, -1, :], axis=-1), aux["cache"]

        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def _admit(self) -> None:
        """Prefill queued requests into free slots. Each prefill runs on
        its own batch-1 cache and the resulting row is inserted into the
        slot pool — never touching in-flight rows."""
        for i in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            t = len(req.prompt)
            single = init_cache(self.cfg, 1, self.L)
            logits, aux = model_apply(
                self.params, self.cfg,
                {"tokens": jnp.asarray(req.prompt)[None, :]},
                cache=single, pos=0)

            def insert(pool_leaf, row_leaf):
                if row_leaf is not None and pool_leaf.ndim >= 1 and \
                        row_leaf.shape[:1] == (1,) and \
                        pool_leaf.shape[0] == self.B:
                    return pool_leaf.at[i].set(row_leaf[0])
                return pool_leaf  # batch-free leaves (e.g. ring pos_ids)

            self.cache = jax.tree_util.tree_map(insert, self.cache,
                                                aux["cache"])
            self.slots[i] = _Slot(req=req, pos=t,
                                  generated=[int(jnp.argmax(logits[0, -1]))])

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            out_len = len(s.generated)
            hit_eos = self.eos_id is not None and s.generated and \
                s.generated[-1] == self.eos_id
            if out_len >= s.req.max_new_tokens or hit_eos or s.pos >= self.L - 1:
                s.req.output = np.asarray(s.generated, np.int32)
                self.done.append(s.req)
                self.slots[i] = _Slot()

    def step(self) -> int:
        """One scheduler tick: admit, decode one token for the active
        cohort, retire. Returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        # cohort = slots sharing the same pos (lockstep decode);
        # pick the largest cohort this tick
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(self.slots[i].pos, []).append(i)
        pos, cohort = max(by_pos.items(), key=lambda kv: len(kv[1]))
        toks = np.zeros((self.B, 1), np.int32)
        for i in cohort:
            toks[i, 0] = self.slots[i].generated[-1]
        prev_cache = self.cache
        next_tok, new_cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), pos)
        # the decode step wrote position `pos` (and advanced recurrent
        # state) for EVERY row; restore the rows that are not in this
        # cohort so their caches are untouched. (A production kernel would
        # use masked per-row writes; one tick of double-buffering is the
        # simple correct equivalent.)
        others = [i for i in range(self.B) if i not in cohort]
        if others:
            idx = jnp.asarray(others)

            def restore(new_leaf, old_leaf):
                if new_leaf.ndim >= 1 and new_leaf.shape[0] == self.B:
                    return new_leaf.at[idx].set(old_leaf[idx])
                return old_leaf
            new_cache = jax.tree_util.tree_map(restore, new_cache, prev_cache)
        self.cache = new_cache
        nt = np.asarray(next_tok)
        for i in cohort:
            self.slots[i].generated.append(int(nt[i]))
            self.slots[i].pos += 1
        self._retire()
        return len(active)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(s.req for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
