"""Continuous-batching request scheduler over a fused per-slot decode step.

Real serving stacks (vLLM/JetStream-style) keep the decode batch full by
slotting new requests into finished sequences' cache rows instead of
waiting for the whole batch to drain. This is the jax-native equivalent:

  * a fixed-shape slot pool (batch B rows) holds the decode state;
  * every tick decodes EVERY active slot in one fused jitted step, each row
    at its own position (per-row scatter cache writes — no lockstep
    cohorts, no double-buffer restore of idle rows: inactive rows' writes
    are masked out inside the kernel);
  * finished slots (EOS or length budget) are refilled from the queue by
    running a per-slot prefill into the shared cache.

Two KV-cache backends, selected by ``paged``:

  * dense (default) — every row reserves ``max_len`` KV positions up front
    (``init_cache``). Admission is gated by free *slots*; memory scales with
    B * max_len regardless of how long requests actually are.
  * paged — a global block pool of ``num_blocks`` blocks of ``block_size``
    tokens per layer plus per-row block tables (``init_paged_cache``).
    Admission is gated by free *blocks*, memory scales with live tokens, and
    ``max_len`` is only a per-row logical cap (it may exceed the dense
    per-slot budget the same total memory would buy). ``BlockAllocator`` is
    the host-side free list; blocks are allocated at admission (prompt + the
    first decode write), grown one block at a time as rows decode across a
    block boundary, and freed at retirement. When the pool is exhausted and
    NO row can advance, the most recently admitted stalled row is preempted
    vLLM-style: its blocks are freed and the request is re-queued at the
    front for recompute-resume (re-prefill of prompt + tokens generated so
    far — greedy decode, and position-keyed sampling where the token at
    position p is drawn with ``fold_in(request_seed, p)``, make the resumed
    continuation exact).

The decode tick samples with ``GenerateConfig`` parity: pass ``gen=`` for
temperature/top-k (greedy by default) and ``Request.seed`` for per-request
reproducibility. In paged mode each tick passes a bucketed *live width* —
the max blocks any row holds, rounded to a power of two — as a static
argument, so the paged attention read (Pallas kernel on TPU, XLA gather
elsewhere; see ``core.attention.paged_attention``) only visits the
allocated block-table prefix and the tick cost tracks live tokens, not the
table width.

The per-row ``pos`` vector / masked-scatter contract the decode step relies
on is documented in ``repro.models.transformer.model_apply`` and
``repro.core.attention``; the architecture narrative lives in
``docs/serving.md``.

Slot and block bookkeeping is host-side python (cheap, O(B) per step); all
tensor work stays jitted with static shapes — the pattern that scales to the
pod-sharded cache (slots = batch rows, already sharded over dp).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    ModelConfig,
    init_cache,
    init_paged_cache,
    model_apply,
)
from repro.serving.decode import GenerateConfig, sample_rows, sample_token_at

Array = jax.Array

_TABLE_KEY = jax.tree_util.DictKey("block_table")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 32
    # per-request sampling seed (used when the batcher's GenerateConfig has
    # temperature > 0); None derives a deterministic default from uid
    seed: Optional[int] = None
    # filled by the scheduler
    output: Optional[np.ndarray] = None
    # internal: tokens generated before a preemption (recompute-resume state)
    resume_generated: Optional[List[int]] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                     # next cache position
    generated: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)  # paged only
    order: int = 0                   # admission sequence number
    key: Optional[np.ndarray] = None  # (2,) uint32 request PRNG key


class BlockAllocator:
    """Host-side free list over the global KV block pool.

    Physical block ids are plain ints in [0, num_blocks); the pool tensors
    live on device, only the *mapping* is host state. ``alloc`` is
    all-or-nothing so a request never holds a partial reservation."""

    def __init__(self, num_blocks: int) -> None:
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks, or None (and no side effect) if not enough."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: List[int]) -> None:
        self._free.extend(blocks)


def _table_leaf(leaf, table: Array):
    """Fit a host-owned (B, W) block table onto a cache table leaf,
    broadcasting over the leading layer-group axis of scanned caches."""
    if leaf.ndim == table.ndim + 1:                  # scanned: (G, B, W)
        return jnp.broadcast_to(table, (leaf.shape[0],) + table.shape)
    return table


def _with_tables(cache, table: Array):
    """Return ``cache`` with every block_table leaf set to ``table`` (B, W)."""
    def set_leaf(path, leaf):
        if path and path[-1] == _TABLE_KEY:
            return _table_leaf(leaf, table)
        return leaf
    return jax.tree_util.tree_map_with_path(set_leaf, cache)


class ContinuousBatcher:
    """Slot-pool scheduler over a shared static KV cache (dense or paged).

    Device state per slot row: KV cache (dense row or block-table view into
    the pool), next position and last sampled token; one jitted decode
    advances all active rows per tick regardless of their (generally
    different) positions."""

    def __init__(self, params, cfg: ModelConfig, batch_size: int,
                 max_len: int, eos_id: Optional[int] = None,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 gen: Optional[GenerateConfig] = None) -> None:
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.L = max_len
        # sampling config for the fused tick (greedy by default — parity
        # with GenerateConfig's temperature/top-k knobs; per-request seeds
        # come from Request.seed). eos_id arg wins over gen.eos_id.
        self._gen = gen if gen is not None else GenerateConfig()
        self.eos_id = eos_id if eos_id is not None else self._gen.eos_id
        self.paged = paged
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._order = 0
        if paged:
            self.block_size = block_size
            n_entries = -(-max_len // block_size)
            # default pool = dense-equivalent memory (B rows of max_len)
            self.num_blocks = num_blocks if num_blocks is not None \
                else batch_size * n_entries
            self.allocator = BlockAllocator(self.num_blocks)
            self.tables = np.full((batch_size, n_entries), -1, np.int32)
            # host tables are mirrored into the device cache lazily: only
            # ticks after an admit/alloc/retire/preempt pay the re-upload
            self._tables_dirty = True
            make_cache = lambda b: init_paged_cache(  # noqa: E731
                cfg, b, max_len, self.num_blocks, block_size)
        else:
            make_cache = lambda b: init_cache(cfg, b, max_len)  # noqa: E731
        self.cache = make_cache(batch_size)
        # admission prefills run against a batch-1 view; the fresh zero
        # template is immutable, so one copy serves every admission. In
        # paged mode only its batch-led leaves (ring/recurrent rows, table)
        # are ever read — build it with a 1-block pool so the template does
        # not duplicate the real pool's device memory
        self._row_template = init_paged_cache(cfg, 1, max_len, 1, block_size) \
            if paged else make_cache(1)
        # one-shot ring prefill cannot exceed the local_attn window (see
        # ROADMAP: chunked ring prefill); recompute-preemption must not
        # create resume prompts that would wrap the ring
        has_ring = any(k == "local_attn"
                       for k in cfg.pattern + cfg.tail_pattern)
        self._ring_limit = min(max_len, cfg.window) \
            if (paged and has_ring and cfg.window) else None
        # which leaves are batch-free (the paged global pools, shared by all
        # rows) vs batch-led (dense/ring KV, recurrent states, block
        # tables): exactly the leaves whose shape ignores the batch argument
        spec1, spec2 = (jax.eval_shape(lambda b=b: make_cache(b))
                        for b in (1, 2))
        self._batch_free = jax.tree_util.tree_map(
            lambda a, b: a.shape == b.shape, spec1, spec2)

        gen_cfg = self._gen

        def _decode(params, cache, tokens, pos, active, keys, live_width):
            # one fused step: every row decodes at its own position; writes
            # of inactive rows are dropped inside model_apply (masked
            # per-row scatter), so idle cache rows are never clobbered.
            # ``live_width`` (static) bounds the paged attention read to the
            # allocated block-table prefix; ``keys`` are per-request PRNG
            # keys — the sampled token at position p is fold_in(key, p), so
            # recompute-resume replays identical samples (see decode.py).
            logits, aux = model_apply(params, cfg, {"tokens": tokens},
                                      cache=cache, pos=pos, active=active,
                                      paged_live_width=live_width)
            next_tok = sample_rows(logits[:, -1, :], gen_cfg, keys, pos + 1)
            return next_tok, aux["cache"]

        self._decode = jax.jit(_decode, static_argnums=(6,))
        self._first_token = jax.jit(
            lambda logits, key, t: sample_token_at(logits, gen_cfg, key, t))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request, rejecting impossible ones up front — a lazy
        admit-time failure would wedge the FIFO queue head and strand every
        in-flight and queued request behind it. (Preemption re-queues
        bypass this: resume lengths are bounded by construction.)"""
        t = len(req.prompt)
        if t > self.L - 1:
            raise ValueError(
                f"request uid={req.uid}: {t} prompt tokens do not fit a "
                f"max_len={self.L} {'row' if self.paged else 'slot'} "
                f"(>= 1 position must remain for decode)")
        if self.paged and self._blocks_for(t + 1) > self.num_blocks:
            raise ValueError(
                f"request uid={req.uid} needs {self._blocks_for(t + 1)} "
                f"blocks; the pool only has {self.num_blocks}")
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def _blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def _row_cache(self, i: int):
        """Batch-1 admission cache for slot ``i``. Dense mode: the fresh
        zero template (batch-1 caches are independent of the pool). Paged
        mode: paged entries reference the LIVE global pools plus this row's
        host block table, while batch-led entries (local_attn rings,
        recurrent states) still start from the fresh template — a slice of
        the shared cache would leak the previous occupant's ring pos_ids /
        recurrent state into the new request's prefill."""
        if not self.paged:
            return self._row_template
        table = jnp.asarray(self.tables[i:i + 1])

        def pick(path, batch_free, fresh_leaf, live_leaf):
            if path and path[-1] == _TABLE_KEY:
                return _table_leaf(fresh_leaf, table)
            return live_leaf if batch_free else fresh_leaf

        return jax.tree_util.tree_map_with_path(
            pick, self._batch_free, self._row_template, self.cache)

    def _merge_row(self, new_cache, i: int) -> None:
        """Fold a batch-1 admission prefill back into the shared cache:
        batch-led leaves are inserted at row ``i``; paged pool leaves are
        adopted whole (the prefill scattered into this row's blocks in
        place — dense mode has no such leaves to adopt); block tables stay
        host-owned."""
        def pick(path, batch_free, live_leaf, new_leaf):
            if path and path[-1] == _TABLE_KEY:
                return live_leaf
            if batch_free:
                return new_leaf if self.paged else live_leaf
            # scanned caches stack layer groups in front: (G, B, ...)
            ax = 1 if path and path[0] == jax.tree_util.DictKey("groups") \
                else 0
            dst = (slice(None),) * ax + (i,)
            src = (slice(None),) * ax + (0,)
            return live_leaf.at[dst].set(new_leaf[src])

        self.cache = jax.tree_util.tree_map_with_path(
            pick, self._batch_free, self.cache, new_cache)

    def _admit(self) -> None:
        """Prefill queued requests into free slots, FIFO. Dense mode gates on
        free slots only; paged mode additionally requires blocks for the
        prompt plus the first decode write (head-of-line: if the front
        request doesn't fit, admission waits rather than skipping it).
        A preempted request re-prefills prompt + generated-so-far and
        resumes its token list."""
        for i in self._free_slots():
            if not self.queue:
                break
            req = self.queue[0]
            resume = req.resume_generated
            toks = req.prompt if not resume else \
                np.concatenate([req.prompt,
                                np.asarray(resume[:-1], np.int32)])
            t = len(toks)
            if self.paged:
                blocks = self.allocator.alloc(self._blocks_for(t + 1))
                if blocks is None:
                    break                       # wait for blocks to free up
                self.queue.pop(0)
                self.tables[i, :len(blocks)] = blocks
                self._tables_dirty = True
            else:
                blocks = []
                self.queue.pop(0)
            logits, aux = model_apply(
                self.params, self.cfg,
                {"tokens": jnp.asarray(toks)[None, :]},
                cache=self._row_cache(i), pos=0)
            # paged: the prefill scattered into this row's pool blocks in
            # place; batch-led state (dense/ring KV, recurrent) comes back
            # batch-1 and is inserted at row i
            self._merge_row(aux["cache"], i)
            key = np.asarray(jax.random.PRNGKey(
                req.seed if req.seed is not None else req.uid))
            if resume:
                gen = list(resume)
                req.resume_generated = None
            else:
                # the first generated token sits at position t: same
                # position-keyed rule as the tick, so admission and decode
                # draw from one coherent per-request stream
                gen = [int(self._first_token(logits[0, -1],
                                             jnp.asarray(key), t))]
            self.slots[i] = _Slot(req=req, pos=t, generated=gen,
                                  blocks=blocks, order=self._order, key=key)
            self._order += 1

    def _preempt(self, i: int) -> None:
        """Evict slot ``i`` for recompute: free its blocks, stash its
        generated tokens on the request, and put it at the queue front."""
        s = self.slots[i]
        s.req.resume_generated = list(s.generated)
        self.allocator.free(s.blocks)
        self.tables[i] = -1
        self._tables_dirty = True
        self.queue.insert(0, s.req)
        self.slots[i] = _Slot()

    def _ensure_blocks(self) -> List[int]:
        """Paged decode-tick allocation: give every active row the block its
        next write position lands in. Rows that cannot get one simply skip
        this tick (their state is untouched, so retrying later is free). If
        the pool is exhausted and *no* row can advance, preempt the most
        recently admitted stalled row and retry; a single stalled row holding
        the whole pool means the pool is simply too small for the request.
        Returns the slot indices that can decode this tick."""
        while True:
            ready, stalled = [], []
            for i, s in enumerate(self.slots):
                if s.req is None:
                    continue
                need = s.pos // self.block_size + 1 - len(s.blocks)
                if need > 0:
                    got = self.allocator.alloc(need)
                    if got is None:
                        stalled.append(i)
                        continue
                    self.tables[i, len(s.blocks):len(s.blocks) + need] = got
                    s.blocks.extend(got)
                    self._tables_dirty = True
                ready.append(i)
            if ready or not stalled:
                return ready
            if len(stalled) == 1:
                s = self.slots[stalled[0]]
                raise RuntimeError(
                    f"block pool too small: request uid={s.req.uid} holds "
                    f"{len(s.blocks)}/{self.num_blocks} blocks and still "
                    f"needs more; increase num_blocks")
            # a preempted row resumes via a one-shot re-prefill of
            # prompt + generated-so-far (= pos tokens); past the local_attn
            # window that prefill would wrap the ring and silently corrupt
            # the continuation, so such rows are not preemptable
            preemptable = [i for i in stalled
                           if self._ring_limit is None
                           or self.slots[i].pos <= self._ring_limit]
            if not preemptable:
                raise RuntimeError(
                    f"block pool exhausted and every stalled row is past "
                    f"the local_attn window ({self._ring_limit} tokens), so "
                    f"none can be preempted for recompute (one-shot ring "
                    f"prefill limit — see ROADMAP: chunked ring prefill); "
                    f"increase num_blocks")
            self._preempt(max(preemptable,
                              key=lambda i: self.slots[i].order))

    def _live_width(self) -> Optional[int]:
        """Static block-table read width for this tick: the max blocks any
        occupied slot holds, rounded up to a power of two (so at most
        log2(W)+1 distinct jit specializations exist). Allocation is
        prefix-dense — tables fill from entry 0 — so every live token of
        every row sits inside the first ``live_width`` entries and slicing
        the READ path there is exact. Returns None in dense mode."""
        if not self.paged:
            return None
        held = max((len(s.blocks) for s in self.slots if s.req is not None),
                   default=1)
        lw = 1 if held <= 1 else 1 << (held - 1).bit_length()
        return min(lw, self.tables.shape[1])

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            out_len = len(s.generated)
            hit_eos = self.eos_id is not None and s.generated and \
                s.generated[-1] == self.eos_id
            if out_len >= s.req.max_new_tokens or hit_eos or s.pos >= self.L - 1:
                s.req.output = np.asarray(s.generated, np.int32)
                self.done.append(s.req)
                if self.paged:
                    self.allocator.free(s.blocks)
                    self.tables[i] = -1
                    self._tables_dirty = True
                self.slots[i] = _Slot()

    def step(self) -> int:
        """One scheduler tick: admit, decode one token for EVERY active
        slot that has cache room, retire. Returns number of decoded slots."""
        # a prefill's first token may already satisfy EOS or the budget;
        # retire-and-refill until the slot set is stable before decoding
        while True:
            self._admit()
            n_done = len(self.done)
            self._retire()
            if len(self.done) == n_done or not self.queue:
                break
        if self.paged:
            run_idx = self._ensure_blocks()
        else:
            run_idx = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not run_idx:
            return 0
        # per-row decode state, derived from the slots each tick (O(B))
        last_tok = np.asarray([s.generated[-1] if s.generated else 0
                               for s in self.slots], np.int32)
        pos = np.asarray([s.pos for s in self.slots], np.int32)
        active = np.zeros((self.B,), bool)
        active[run_idx] = True
        keys = np.stack([s.key if s.key is not None
                         else np.zeros((2,), np.uint32) for s in self.slots])
        if self.paged and self._tables_dirty:
            self.cache = _with_tables(self.cache, jnp.asarray(self.tables))
            self._tables_dirty = False
        # the decode step returns its block tables unchanged, so in steady
        # state (no admissions/retirements) the paged tick is as cheap as
        # the dense one: no table upload, no tree surgery
        next_tok, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(last_tok)[:, None],
            jnp.asarray(pos), jnp.asarray(active), jnp.asarray(keys),
            self._live_width())
        nt = np.asarray(next_tok)
        for i in run_idx:
            self.slots[i].generated.append(int(nt[i]))
            self.slots[i].pos += 1
        self._retire()
        return len(run_idx)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(s.req for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
