"""Continuous-batching request scheduler over a fused per-slot decode step.

Real serving stacks (vLLM/JetStream-style) keep the decode batch full by
slotting new requests into finished sequences' cache rows instead of
waiting for the whole batch to drain. This is the jax-native equivalent:

  * a fixed-shape slot pool (batch B, max_len L) holds the KV cache;
  * every tick decodes EVERY active slot in one fused jitted step, each row
    at its own position (per-row scatter cache writes — no lockstep
    cohorts, no double-buffer restore of idle rows: inactive rows' writes
    are masked out inside the kernel);
  * finished slots (EOS or length budget) are refilled from the queue by
    running a per-slot prefill into the shared cache row.

Slot bookkeeping is host-side python (cheap, O(B) per step); all tensor
work stays jitted with static shapes — the pattern that scales to the
pod-sharded cache (slots = batch rows, already sharded over dp).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, init_cache, model_apply

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 32
    # filled by the scheduler
    output: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                     # next cache position
    generated: List[int] = dataclasses.field(default_factory=list)


class ContinuousBatcher:
    """Slot-pool scheduler over a shared static KV cache.

    Device state per slot row: KV cache, next position and last sampled
    token; one jitted decode advances all active rows per tick regardless
    of their (generally different) positions."""

    def __init__(self, params, cfg: ModelConfig, batch_size: int,
                 max_len: int, eos_id: Optional[int] = None) -> None:
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.L = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, batch_size, max_len)
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: List[Request] = []
        self.done: List[Request] = []

        def _decode(params, cache, tokens, pos, active):
            # one fused step: every row decodes at its own position; writes
            # of inactive rows are dropped inside model_apply (masked
            # per-row scatter), so idle cache rows are never clobbered.
            logits, aux = model_apply(params, cfg, {"tokens": tokens},
                                      cache=cache, pos=pos, active=active)
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return next_tok, aux["cache"]

        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def _admit(self) -> None:
        """Prefill queued requests into free slots. Each prefill runs on
        its own batch-1 cache and the resulting row is inserted into the
        slot pool — never touching in-flight rows."""
        for i in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            t = len(req.prompt)
            single = init_cache(self.cfg, 1, self.L)
            logits, aux = model_apply(
                self.params, self.cfg,
                {"tokens": jnp.asarray(req.prompt)[None, :]},
                cache=single, pos=0)

            def insert(path, pool_leaf, row_leaf):
                # scanned caches stack layer groups in front: (G, B, L, ...)
                ax = 1 if path and path[0] == jax.tree_util.DictKey("groups") \
                    else 0
                if row_leaf is not None and pool_leaf.ndim > ax and \
                        row_leaf.shape[ax] == 1 and \
                        pool_leaf.shape[ax] == self.B:
                    dst = (slice(None),) * ax + (i,)
                    src = (slice(None),) * ax + (0,)
                    return pool_leaf.at[dst].set(row_leaf[src])
                return pool_leaf  # batch-free leaves

            self.cache = jax.tree_util.tree_map_with_path(
                insert, self.cache, aux["cache"])
            first = int(jnp.argmax(logits[0, -1]))
            self.slots[i] = _Slot(req=req, pos=t, generated=[first])

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            out_len = len(s.generated)
            hit_eos = self.eos_id is not None and s.generated and \
                s.generated[-1] == self.eos_id
            if out_len >= s.req.max_new_tokens or hit_eos or s.pos >= self.L - 1:
                s.req.output = np.asarray(s.generated, np.int32)
                self.done.append(s.req)
                self.slots[i] = _Slot()

    def step(self) -> int:
        """One scheduler tick: admit, decode one token for EVERY active
        slot, retire. Returns number of active slots."""
        # a prefill's first token may already satisfy EOS or the budget;
        # retire-and-refill until the slot set is stable before decoding
        while True:
            self._admit()
            n_done = len(self.done)
            self._retire()
            if len(self.done) == n_done or not self.queue:
                break
        active_idx = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active_idx:
            return 0
        # per-row decode state, derived from the slots each tick (O(B))
        last_tok = np.asarray([s.generated[-1] if s.generated else 0
                               for s in self.slots], np.int32)
        pos = np.asarray([s.pos for s in self.slots], np.int32)
        active = np.asarray([s.req is not None for s in self.slots])
        next_tok, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(last_tok)[:, None],
            jnp.asarray(pos), jnp.asarray(active))
        nt = np.asarray(next_tok)
        for i in active_idx:
            self.slots[i].generated.append(int(nt[i]))
            self.slots[i].pos += 1
        self._retire()
        return len(active_idx)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(s.req for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
