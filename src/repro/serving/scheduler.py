"""Token-budget continuous-batching scheduler over one fused mixed step.

Real serving stacks (vLLM/JetStream/Sarathi-style) do not run prefill and
decode as separate phases: every engine tick assembles ONE forward pass of
up to ``token_budget`` tokens in which decoding rows contribute 1 token
each and admitted-but-unfinished prompts contribute a prefill *chunk* —
several chunks from different requests batched together, interleaved with
the decode rows. This module is the jax-native equivalent:

  * a fixed-shape slot pool (batch B rows) holds all request state;
  * each tick carves chunks (``PrefillState`` cursors + budget accounting),
    left-aligns every row's contribution into a ``(B, T)`` token block
    (T = the bucketed max contribution), and runs one jitted
    ``step_rows`` forward: per-row ``pos`` vectors place each row at its
    own absolute position, a per-token ``active`` mask drops the padding
    tail's cache writes, and only each row's LAST real token's logits are
    consumed (chunk-aware sampling — a non-final chunk discards them, a
    final chunk samples the request's first token, a decode row its next);
  * there is no separate admission prefill: admission just binds a slot,
    resets its row state, and lets the tick stream the prompt in — so
    decode rows keep advancing while prompts prefill, and a prompt longer
    than a ``local_attn`` window is admissible (chunks are capped at the
    window; the ring read path handles multi-token chunks — the seed's
    one-shot ring prefill limit is gone).

Admission is (priority, arrival)-ordered — ``Request.priority`` (higher
first), FIFO among equals, so equal-priority traffic cannot starve — and
gated by a free-block *watermark* in paged mode (``admit_watermark``:
admit only while ``free_blocks >= watermark``), replacing the seed's bare
FIFO head-of-line.

Two KV-cache backends, selected by ``paged``:

  * dense (default) — every row reserves ``max_len`` KV positions up front
    (``init_cache``). Admission is gated by free *slots*; memory scales with
    B * max_len regardless of how long requests actually are.
  * paged — a global block pool of ``num_blocks`` blocks of ``block_size``
    tokens per layer plus per-row block tables (``init_paged_cache``).
    ``BlockAllocator`` is the host-side free list; blocks are allocated as
    chunks and decode writes land in them (a chunk shrinks to the blocks it
    can get — partial prefill progress is fine) and freed at retirement.
    When the pool is exhausted and NO row can advance, the most recently
    admitted stalled row is preempted vLLM-style: its blocks are freed and
    the request is re-queued (keeping its original arrival rank) for
    recompute-resume. The resume is just a longer prompt re-entering the
    SAME chunked-prefill path — greedy decode, and position-keyed sampling
    where the token at position p is drawn with ``fold_in(request_seed,
    p)``, make the resumed continuation exact, and chunking makes rows past
    a ``local_attn`` window preemptable too (the seed had to refuse them).

The decode tick samples with ``GenerateConfig`` parity: pass ``gen=`` for
temperature/top-k (greedy by default) and ``Request.seed`` for per-request
reproducibility. In paged mode each tick passes a bucketed *live width* —
the max blocks any row holds, rounded to a power of two — as a static
argument plus a per-row live-width vector, so the paged attention read
(Pallas kernel on TPU, XLA gather elsewhere; see
``core.attention.paged_attention``) only visits the allocated block-table
prefix and each row's read is masked at its own block count.

Models with recurrent blocks (griffin/xlstm) cannot express ragged rows
(a recurrence has no per-token write index to mask), so for those configs
the engine splits each tick into a decode sub-step and a uniform-length
prefill sub-step instead of one mixed ragged step — still chunked, still
non-stalling, just not interleaved within a single forward.

The per-row ``pos`` vector / masked per-token scatter contract the step
relies on is documented in ``repro.models.transformer.model_apply`` and
``repro.core.attention``; the architecture narrative lives in
``docs/serving.md``.

Slot and block bookkeeping is host-side python (cheap, O(B) per step); all
tensor work stays jitted with static shapes — (T, live_width) pairs are
bucketed to powers of two so at most O(log(budget) * log(W)) step
specializations exist.

INT8 serving (the paper's payoff, live): ``qconfig=`` turns the tick into
a W8A8 forward — activation ranges are PTQ-calibrated ONCE at engine
construction against a few synthetic batches (``quant.ptq.calibrate``),
the matmul weights are pre-quantized onto the params tree
(``quant.int8_weights.attach_int8_weights``) and every linear routes
through the int8 MXU kernel with those static ranges (see
``nn.layers.linear_apply``); the calibrated context is captured by the
jitted step as closure constants, so the tick compiles exactly like the
fp one. ``kv_int8=`` (default: on whenever ``qconfig`` is given with
``paged=True``) stores the paged KV pools as int8 with per-slot scale
vectors — quantize fused into the cache scatter, dequant into both paged
read backends (``init_paged_cache(kv_int8=True)``). KV block memory drops
~3.5x for typical head shapes, so an equal-byte pool admits proportionally
more concurrent rows; serving stays bitwise invariant to chunking, slot
assignment and preemption-resume because each token is quantized exactly
once at write (see ``quant.kv_cache``). ``kv_int8=True`` alone (no
``qconfig``) is allowed: fp matmuls over a quantized cache.

Robustness layer (SLO-aware scheduling, swapped preemption, degradation —
see ``docs/serving.md`` "Traffic, SLOs, and failure handling"):

  * ``step(now=...)`` threads a caller-owned clock (the open-loop workload
    harness in ``serving.workload`` drives a deterministic virtual clock;
    ``now`` defaults to an internal tick counter). ``Request`` grows
    ``deadline`` (absolute, same clock) and ``timeout`` (relative to
    submission): expired/timed-out requests are cancelled the same tick —
    queued, mid-prefill or decoding — with their blocks released, and land
    in ``self.failed`` with a status string. Queued requests whose minimum
    remaining work provably cannot meet their deadline are shed early
    (``shed_infeasible``), and deadline-bearing requests are admitted and
    prefill-carved earliest-deadline-first within a priority level.
    ``prefill_budget`` caps the prefill share of each tick's token budget
    so a burst of arrivals cannot inflate decode-tick p99.
  * swapped preemption: with ``swap_break_even_tokens`` set, a preemption
    victim whose cached context is long copies its live pool blocks (and
    int8 scale vectors) plus its batch-led row state out to host memory
    (``SwappedState``) and copies them back in on resume — bit-exact, no
    recompute. Short victims keep the recompute-resume path: swap cost
    scales with the row's KV *bytes* (linear in tokens) while recompute
    re-runs the model over all cached tokens (much more expensive per
    token), so the bytes-vs-recompute rule reduces to a token threshold.
    Swap-in is all-or-nothing; after ``swap_retry_limit`` failed attempts
    (pool pressure or an injected denial) the request degrades to
    recompute-resume, which can always make incremental progress.
  * fault tolerance: every block release goes through one audited
    ``_release_blocks`` helper, ``BlockAllocator.free`` rejects double
    frees and foreign ids, and ``audit()`` checks the full invariant
    (every block exactly one of free / owned-by-live-row; tables mirror
    slot state; swapped requests hold zero device blocks) —
    ``debug_audit=True`` runs it after every tick. A spurious allocation
    failure (the allocator denies despite free blocks — ``serving.chaos``
    injects these) is treated as transient: the tick stalls and retries
    instead of preempting; once the fault persists past
    ``fault_shed_after`` ticks the engine degrades by policy, shedding
    exactly one victim per tick in strict priority order (lowest first,
    newest arrival among equals). ``on_pool_exhausted="shed"`` converts
    the one remaining hard failure (a single request larger than the whole
    pool) into a shed as well.

Prefix sharing + parallel sampling (see docs/serving.md "Prefix sharing
& copy-on-write" and ``serving.prefix_cache``): ``BlockAllocator`` is
refcounted — blocks are owned, not merely held, and return to the free
list only at zero owners. ``prefix_cache=True`` (paged, all-attn
configs) consults a token-ids-keyed trie at admission: a prompt whose
prefix is cached maps those FULL blocks straight into its table
(acquiring refs) and prefills only the divergent tail, so cached-prefix
TTFT collapses to ~one tick; completed prefills publish their prompt
blocks back, and LRU eviction of sole-owner nodes keeps the cache from
ever blocking a live allocation. ``Request(n=k)`` admits once and fans
into k branches (branch i seeded ``base + i``): the leader prefills,
siblings attach to a snapshot of its prompt blocks at refcount k and
diverge via copy-on-write — the first write into a still-shared block
remaps the row to a fresh block with a device-side content copy
(``copy_pool_blocks``), jitted separately so the decode tick's compile
budget is untouched. ``audit()``'s invariant generalizes to: every
block's refcount equals its owner count across slot tables + trie +
group snapshots. Sharing is bitwise-invisible: KV bits (fp, or int8
with its per-token scale) are pure functions of (token, position), so a
shared read equals the cold prefill the sharing replaced.

Speculative decoding (``spec=SpecConfig(k=...)`` — see
``serving.speculate`` and docs/serving.md "Speculative decoding"): each
decode row feeds its last token PLUS up to k model-free n-gram drafts
into the same fused tick; ``make_spec_step`` returns the full (B, T)
target matrix and the host accepts the longest draft prefix matching it,
advancing the row 1..k+1 tokens per tick — bitwise identical to the
non-speculative stream because the accept test IS position-keyed
sampling. Scheduler-side that means: ``_plan`` grows a decode row's
block table to cover 1+k writes (possibly crossing several block
boundaries in one tick — ``_grow_blocks`` already handles multi-block
growth and cursor-block CoW, and a short grant just truncates the
draft), rejected drafts leave stale-but-causally-hidden cache entries
that the row's own later writes overwrite (swap copies them harmlessly;
recompute-resume never rebuilds them), and accounting splits into
``last_tick_tokens`` (FED tokens — the compute the tick paid, what the
virtual clock charges) vs ``last_tick_new_tokens`` (tokens actually
banked into outputs — what goodput/TPOT count). Speculation requires an
all-'attn' pattern (ring/recurrent writes cannot be causally hidden)
and composes with paged/dense, fp/int8, prefix sharing, ``Request(n)``
branches, swap and preemption — all equivalence-tested.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    ModelConfig,
    copy_pool_blocks,
    init_cache,
    init_paged_cache,
    model_apply,
)
from repro.quant.int8_weights import attach_int8_weights
from repro.quant.ptq import calibrate
from repro.quant.qconfig import NO_QUANT, QConfig
from repro.serving.decode import (
    GenerateConfig,
    make_mixed_step,
    make_spec_step,
)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.speculate import NGramDrafter, SpecConfig

Array = jax.Array

_TABLE_KEY = jax.tree_util.DictKey("block_table")
_GROUPS_KEY = jax.tree_util.DictKey("groups")
_RECURRENT_KINDS = ("griffin", "mlstm", "slstm")


class AllocatorAuditError(RuntimeError):
    """A block-accounting invariant was violated (leak, double free,
    foreign id, stale table mirror). Raised by ``BlockAllocator.free`` and
    ``ContinuousBatcher.audit`` — the chaos harness asserts this never
    fires under any fault plan."""


# eq=False (here and _SampleGroup): live requests are identity objects —
# parallel-sampling branches share uid AND prompt, so a field-wise ==
# would compare ndarray prompts and raise instead of answering, breaking
# list membership (queue, _groups) on the first same-uid pair
@dataclasses.dataclass(eq=False)
class Request:
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 32
    # admission priority: HIGHER is served first; FIFO (arrival order)
    # among equal priorities, so equal-priority traffic cannot starve
    priority: int = 0
    # per-request sampling seed (used when the batcher's GenerateConfig has
    # temperature > 0); None derives a deterministic default from uid
    seed: Optional[int] = None
    # parallel sampling: n completions of the same prompt. The request
    # admits once — internally it expands into n branch requests where
    # branch i samples with seed base+i (base = seed or uid), so the
    # result is bitwise what n independent Requests with those seeds
    # would produce; on shareable engines (paged, all-attn) the branches
    # share the prompt's blocks at refcount n and diverge via
    # copy-on-write. Results aggregate into ``outputs`` (index order);
    # ``output`` aliases outputs[0].
    n: int = 1
    # --- SLOs (see step(now=...): all times share the caller's clock) ---
    # absolute completion deadline: past it the request is cancelled
    # ("expired") and its tokens no longer count toward goodput; queued
    # requests that provably cannot meet it are shed early
    deadline: Optional[float] = None
    # relative cap on time since submission ("timeout" when exceeded)
    timeout: Optional[float] = None
    # filled by the scheduler
    output: Optional[np.ndarray] = None
    # parallel sampling (n > 1): per-branch outputs in branch order
    outputs: Optional[List[np.ndarray]] = None
    # lifecycle: queued -> running -> done | cancelled | expired | timeout
    # | shed (failed statuses land the request in batcher.failed)
    status: str = "queued"
    submit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # internal: host-side copy-out of a swap-preempted row (swap-resume)
    swapped: Optional["SwappedState"] = None
    # internal: tokens generated before a preemption (recompute-resume state)
    resume_generated: Optional[List[int]] = None
    # internal: submission sequence number (admission tie-break; a preempted
    # request keeps its original arrival, so re-queueing cannot demote it
    # behind later arrivals of the same priority)
    arrival: Optional[int] = None
    # internal: parallel-sampling bookkeeping (set on the expanded branch
    # requests, never on the parent the caller submitted)
    group: Optional["_SampleGroup"] = None
    branch: int = 0


@dataclasses.dataclass(eq=False)
class _SampleGroup:
    """Bookkeeping for one ``Request(n=k)`` parallel-sampling group.

    The parent request never enters the queue; it expands into ``n``
    branch requests sharing its uid. On shareable engines the branches'
    admission is staged: the LEADER (lowest live branch) prefills the
    prompt normally; when its prefill completes the group snapshots the
    prompt's blocks (one extra allocator reference each, ``shared``) and
    flips ``ready`` — only then do the siblings become admissible, each
    binding with its position cursor at ``len(prompt) - 1``: it acquires
    the snapshot blocks, re-feeds just the LAST prompt token (one-token
    prefill, so its first sample sees the same logits the leader's did),
    and its first divergent write copy-on-writes the shared tail block.
    ``unshared`` tracks which branches still get to take the snapshot
    (one admission each — a preempted branch resumes through the normal
    recompute/trie path); the snapshot refs drop as soon as every branch
    has taken (or terminally lost) its turn. Terminal branches collect in
    ``results``; the last one landing folds the group into the parent."""
    parent: Request
    n: int
    prompt_len: int
    leader: int = 0
    ready: bool = False
    shared: List[int] = dataclasses.field(default_factory=list)
    unshared: set = dataclasses.field(default_factory=set)
    branches: List[Request] = dataclasses.field(default_factory=list)
    results: Dict[int, Request] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PrefillState:
    """Chunked-prefill cursor of one admitted request.

    ``feed`` is everything that must stream through the model before the
    request can decode: the prompt, plus — for a recompute-resume after
    preemption — all but the last of its previously generated tokens (the
    last one becomes the first decode input again). ``done`` tokens of it
    are already written to the cache; each tick the scheduler carves the
    next chunk ``feed[done:done+c]`` against the token budget."""
    feed: np.ndarray                 # (T,) int32
    done: int = 0
    # recompute-resume: the previously generated tokens, restored verbatim
    # when the prefill completes (the final chunk's sample is discarded —
    # position-keyed sampling would reproduce it exactly anyway)
    resume: Optional[List[int]] = None

    @property
    def remaining(self) -> int:
        return len(self.feed) - self.done


@dataclasses.dataclass
class SwappedState:
    """Host-side copy-out of a swap-preempted row's live device state.

    ``pool`` maps cache-leaf paths of the batch-free pool leaves (the K/V
    block pools and, for int8 KV, their per-slot scale vectors) to the
    victim's block rows in block-table order; ``row`` maps batch-led leaf
    paths (ring KV / pos_ids, recurrent h/conv/cell) to the victim's row
    slice. Together with the slot bookkeeping below, a swap-in restores
    the row bit-exactly into freshly allocated blocks — no recompute.
    The copied blocks themselves are FREED at swap-out: a swapped request
    holds zero device blocks (the allocator audit checks this)."""
    pool: Dict[Tuple, np.ndarray]
    row: Dict[Tuple, np.ndarray]
    n_blocks: int
    pos: int
    generated: List[int]
    prefill: Optional[PrefillState]
    key: Optional[np.ndarray]
    nbytes: int
    attempts: int = 0        # failed swap-in tries (bounded retry)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                     # next cache position (= tokens written)
    generated: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)  # paged only
    order: int = 0                   # admission sequence number
    key: Optional[np.ndarray] = None  # (2,) uint32 request PRNG key
    prefill: Optional[PrefillState] = None   # None once fully prefilled


class BlockAllocator:
    """Host-side REFCOUNTED free list over the global KV block pool.

    Physical block ids are plain ints in [0, num_blocks); the pool tensors
    live on device, only the *mapping* is host state. Every block carries
    an ownership count: ``alloc`` hands out blocks at refcount 1,
    ``acquire`` adds an owner to a live block (prefix-trie publication, a
    sampling-group snapshot, a row mapping a cached prefix), ``release``
    drops one — the block returns to the free list only when its LAST
    owner lets go, which is what makes prefix sharing, copy-on-write
    divergence and swap-out of shared rows ("copy, don't free, while
    another owner holds it") all fall out of one rule.

    A single ``alloc`` call is all-or-nothing, but callers may take less
    than they ultimately want: ``_grow_blocks`` claims
    ``min(need, available)`` so a prefill chunk shrinks to partial
    progress instead of stalling — a row CAN hold blocks for writes it
    has not made yet (they are used on a later tick, or returned
    wholesale at preemption/retirement)."""

    def __init__(self, num_blocks: int) -> None:
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self._refs = [0] * num_blocks

    @property
    def available(self) -> int:
        return len(self._free)

    def refcount(self, block: int) -> int:
        """Current owner count of ``block`` (0 = free)."""
        self._check(block)
        return self._refs[block]

    def _check(self, b: int) -> None:
        if not 0 <= b < self.num_blocks:
            raise AllocatorAuditError(f"foreign block id {b} "
                                      f"(pool has {self.num_blocks})")

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks at refcount 1, or None (and no side effect)
        if not enough are free."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._refs[b] = 1
        return got

    def acquire(self, blocks: List[int]) -> None:
        """Add one owner to each (already-live) block. Acquiring a FREE
        block raises — ownership can only be shared from an existing
        owner, never conjured."""
        for b in blocks:
            self._check(b)
            if self._refs[b] == 0:
                raise AllocatorAuditError(
                    f"acquire of free block {b} (no existing owner)")
            self._refs[b] += 1

    def release(self, blocks: List[int]) -> None:
        """Drop one owner per block; a block whose count hits zero
        returns to the free list. Over-release (the refcount edition of a
        double free) and foreign ids raise ``AllocatorAuditError`` instead
        of silently corrupting the pool — every release path goes through
        the scheduler's audited ``_release_blocks`` (or the trie/group
        teardown, which the audit also counts), so a violation here is a
        real bug."""
        for b in blocks:
            self._check(b)
            if self._refs[b] == 0:
                raise AllocatorAuditError(f"double free of block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)

    # historical name: pre-refcount callers (and tests) say "free";
    # with ownership counts a free is exactly a release
    free = release

    def free_list(self) -> List[int]:
        """Snapshot of the free block ids (audit surface)."""
        return list(self._free)


def _table_leaf(leaf, table: Array):
    """Fit a host-owned (B, W) block table onto a cache table leaf,
    broadcasting over the leading layer-group axis of scanned caches."""
    if leaf.ndim == table.ndim + 1:                  # scanned: (G, B, W)
        return jnp.broadcast_to(table, (leaf.shape[0],) + table.shape)
    return table


def _with_tables(cache, table: Array):
    """Return ``cache`` with every block_table leaf set to ``table`` (B, W)."""
    def set_leaf(path, leaf):
        if path and path[-1] == _TABLE_KEY:
            return _table_leaf(leaf, table)
        return leaf
    return jax.tree_util.tree_map_with_path(set_leaf, cache)


def _bucket(n: int) -> int:
    """Round up to a power of two (bounds jit specializations)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _calibrate_engine(params, cfg: ModelConfig, qconfig: QConfig,
                      max_len: int, num_batches: int):
    """PTQ-calibrate activation ranges for the W8A8 serving tick.

    Runs ONCE at engine construction: a few synthetic uniform-token batches
    stream through the UN-jitted forward in 'collect' mode
    (``quant.ptq.calibrate``), the estimators close into static per-site
    (s, z), and the context flips to 'int8' — from then on the calibrated
    ranges are python-float closure constants of the jitted tick. Synthetic
    calibration is exactly the deployment-friendly protocol the paper
    argues the outlier-free models tolerate: per-tensor static ranges with
    no data-dependent tuning."""
    t = max(1, min(32, max_len, cfg.max_seq_len))
    key = jax.random.PRNGKey(0)
    batches = [
        {"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                      (2, t), 0, cfg.vocab_size)}
        for i in range(num_batches)
    ]

    def apply_fn(p, batch, ctx):
        return model_apply(p, cfg, batch, ctx=ctx)[0]

    ctx = calibrate(apply_fn, params, batches, qconfig,
                    num_batches=num_batches)
    ctx.use_int8_runtime()
    return ctx


class ContinuousBatcher:
    """Token-budget slot-pool scheduler over a shared static KV cache
    (dense or paged).

    Device state per slot row: KV cache (dense row or block-table view into
    the pool), next position and last sampled token; one jitted mixed step
    advances every runnable row per tick — decode rows by one token,
    prefilling rows by a prompt chunk — regardless of their (generally
    different) positions and phase."""

    def __init__(self, params, cfg: ModelConfig, batch_size: int,
                 max_len: int, eos_id: Optional[int] = None,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 gen: Optional[GenerateConfig] = None,
                 token_budget: int = 256,
                 prefill_chunk: Optional[int] = None,
                 admit_watermark: int = 0,
                 qconfig: Optional[QConfig] = None,
                 kv_int8: Optional[bool] = None,
                 calib_batches: int = 4,
                 prefill_budget: Optional[int] = None,
                 swap_break_even_tokens: Optional[int] = None,
                 swap_pool_bytes: Optional[int] = None,
                 swap_retry_limit: int = 3,
                 shed_infeasible: bool = True,
                 fault_shed_after: int = 8,
                 on_pool_exhausted: str = "raise",
                 prefix_cache: bool = False,
                 spec: Optional[SpecConfig] = None,
                 debug_audit: bool = False) -> None:
        # ---- INT8 serving (W8A8 tick + quantized paged KV) -------------
        if kv_int8 is None:
            kv_int8 = qconfig is not None and paged
        if kv_int8 and not paged:
            raise ValueError(
                "kv_int8 requires paged=True: the int8 KV layout is the "
                "block pool + per-slot scale vectors (init_paged_cache)")
        self.kv_int8 = bool(kv_int8)
        self.qconfig = qconfig
        self._qctx = NO_QUANT
        if qconfig is not None:
            # W8A8 needs per-layer calibration sites and per-layer int8
            # weight slices, so the engine runs the unrolled layer path
            # (functionally identical — stacked scanned params are
            # tree_slice'd per group by model_apply's unrolled branch)
            if cfg.scan_layers:
                cfg = dataclasses.replace(cfg, scan_layers=False)
            self._qctx = _calibrate_engine(params, cfg, qconfig, max_len,
                                           calib_batches)
            params = attach_int8_weights(params, skip=qconfig.skip_patterns)
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.L = max_len
        # sampling config for the fused tick (greedy by default — parity
        # with GenerateConfig's temperature/top-k knobs; per-request seeds
        # come from Request.seed). eos_id arg wins over gen.eos_id.
        self._gen = gen if gen is not None else GenerateConfig()
        self.eos_id = eos_id if eos_id is not None else self._gen.eos_id
        self.paged = paged
        if token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        self.token_budget = token_budget
        self.admit_watermark = admit_watermark
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # requests that left the engine without completing: cancelled,
        # expired (deadline), timeout, or shed (infeasible / persistent
        # faults / pool exhaustion under on_pool_exhausted="shed")
        self.failed: List[Request] = []
        self._order = 0
        self._arrival = 0
        # ---- SLO / robustness knobs ------------------------------------
        # per-tick cap on PREFILL tokens (None = whole remaining budget):
        # bounds the mixed tick's size when arrivals burst, protecting
        # decode-tick p99 at a TTFT cost
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 (or None)")
        self.prefill_budget = prefill_budget
        # swap-vs-recompute cost rule threshold (None = swap disabled):
        # victims with >= this many cached tokens copy out, shorter ones
        # recompute (see _swap_eligible for the bytes-vs-recompute story)
        self.swap_break_even_tokens = swap_break_even_tokens
        self.swap_pool_bytes = swap_pool_bytes   # host swap capacity cap
        self.swap_retry_limit = swap_retry_limit
        self.shed_infeasible = shed_infeasible
        self.fault_shed_after = fault_shed_after
        if on_pool_exhausted not in ("raise", "shed"):
            raise ValueError("on_pool_exhausted must be 'raise' or 'shed'")
        self.on_pool_exhausted = on_pool_exhausted
        self.debug_audit = debug_audit
        # caller-owned clock (step(now=...)); defaults to a tick counter
        self.now = 0.0
        self._tick_ewma: Optional[float] = None   # est. virtual tick cost
        self._prev_advanced = False
        self._alloc_fault = False      # spurious alloc denial seen this tick
        self._fault_streak = 0         # consecutive faulted no-progress ticks
        self._swap_bytes = 0           # host bytes currently held by swaps
        # chaos hook: called before each swap-in; returning False denies it
        # (counts as a retry attempt -> bounded degradation to recompute)
        self._swap_in_gate: Optional[Callable[[Request], bool]] = None
        # total REAL tokens processed by the most recent step() across all
        # sub-steps — the workload harness's virtual-clock cost input.
        # With speculation this counts FED tokens (drafts included,
        # accepted or not): it is the tick's compute cost, not its yield
        self.last_tick_tokens = 0
        # tokens BANKED into request outputs by the most recent step():
        # decode advances (1..k+1 per row under speculation) plus each
        # completed prefill's first token — the goodput/TPOT numerator
        self.last_tick_new_tokens = 0
        # counts vector of the most recent sub-step (observability + tests:
        # a mixed tick shows >= 2 entries > 1 next to entries == 1)
        self.last_counts: Optional[np.ndarray] = None
        if paged:
            self.block_size = block_size
            n_entries = -(-max_len // block_size)
            # default pool = dense-equivalent memory (B rows of max_len)
            self.num_blocks = num_blocks if num_blocks is not None \
                else batch_size * n_entries
            self.allocator = BlockAllocator(self.num_blocks)
            self.tables = np.full((batch_size, n_entries), -1, np.int32)
            # host tables are mirrored into the device cache lazily: only
            # ticks after an admit/alloc/retire/preempt pay the re-upload
            self._tables_dirty = True
            make_cache = lambda b: init_paged_cache(  # noqa: E731
                cfg, b, max_len, self.num_blocks, block_size,
                kv_int8=self.kv_int8)
        else:
            make_cache = lambda b: init_cache(cfg, b, max_len)  # noqa: E731
        self.cache = make_cache(batch_size)
        # fresh batch-1 state template: admission resets the slot's
        # batch-led rows (ring pos_ids, recurrent states, dense KV) from it
        # so the previous occupant cannot leak into the new request's
        # prefill. In paged mode only its batch-led leaves are ever read —
        # build it with a 1-block pool so the template does not duplicate
        # the real pool's device memory
        self._row_template = init_paged_cache(cfg, 1, max_len, 1, block_size,
                                              kv_int8=self.kv_int8) \
            if paged else make_cache(1)
        kinds = cfg.pattern + cfg.tail_pattern
        # recurrent states have no per-token write index to mask, so ragged
        # mixed steps are not expressible — such configs run split
        # decode/uniform-prefill sub-steps instead (see module docstring)
        self._uniform = any(k in _RECURRENT_KINDS for k in kinds)
        # ---- prefix sharing / parallel sampling ------------------------
        # sharing rides on the paged attn pools only: ring (local_attn)
        # and recurrent layers keep batch-led PER-ROW state that a shared
        # block cannot carry, so those configs run sampling branches
        # independently and cannot cache prefixes
        self._can_share = paged and all(k == "attn" for k in kinds)
        # ---- speculative decoding --------------------------------------
        # sound only for global-attn KV (dense or paged): a rejected
        # draft's cache write is causally hidden (every read path masks
        # keys at positions > q) and overwritten by the row's own next
        # writes before its position passes it — but a local_attn RING
        # write at pos % window clobbers live in-window history, and a
        # recurrent state has no per-token position to hide behind
        self.spec = spec
        self._drafter: Optional[NGramDrafter] = None
        self._tick_drafts: Dict[int, List[int]] = {}
        # observability: drafted vs accepted totals (accept rate =
        # spec_accepted / spec_drafted), read by tests + the benchmark
        self.spec_drafted = 0
        self.spec_accepted = 0
        if spec is not None:
            if not all(k == "attn" for k in kinds):
                raise ValueError(
                    "spec=SpecConfig(...) requires an all-'attn' layer "
                    "pattern: rejected draft writes are only causally "
                    "hidden in a global-attn KV cache — a local_attn "
                    "ring write clobbers in-window history and "
                    "recurrent states have no per-token write to mask")
            self._drafter = NGramDrafter(spec)
        self.prefix_cache: Optional[PrefixCache] = None
        if prefix_cache:
            if not self._can_share:
                raise ValueError(
                    "prefix_cache=True requires paged=True and an "
                    "all-'attn' layer pattern: ring/recurrent layers keep "
                    "per-row state a shared block cannot carry")
            self.prefix_cache = PrefixCache(block_size, self.allocator)
        self._groups: List[_SampleGroup] = []     # live sampling groups
        # observability: copy-on-write block copies performed, admissions
        # that mapped a shared prefix, and prompt tokens skipped that way
        self.cow_copies = 0
        self.shared_admissions = 0
        self.shared_tokens = 0
        if paged:
            # device half of copy-on-write (see transformer.copy_pool_blocks):
            # jitted separately from the decode tick so CoW adds zero
            # specializations to the tick's compile budget; (n,) index pairs
            # are pow-2 padded by _copy_blocks so this fn compiles O(log B)
            # times at most
            self._cow_fn = jax.jit(copy_pool_blocks, donate_argnums=(0,))
        # a prefill chunk on a local_attn layer must fit the ring, and its
        # own writes must not collide inside it
        ring_cap = min(max_len, cfg.window) \
            if (any(k == "local_attn" for k in kinds) and cfg.window) \
            else token_budget
        self._chunk_cap = min(prefill_chunk or token_budget, token_budget,
                              ring_cap)
        # which leaves are batch-free (the paged global pools, shared by all
        # rows) vs batch-led (dense/ring KV, recurrent states, block
        # tables): exactly the leaves whose shape ignores the batch argument
        spec1, spec2 = (jax.eval_shape(lambda b=b: make_cache(b))
                        for b in (1, 2))
        self._batch_free = jax.tree_util.tree_map(
            lambda a, b: a.shape == b.shape, spec1, spec2)

        # the jitted fused tick lives with the other serving programs in
        # decode.py; calibrated int8 ranges ride along as closure
        # constants. A speculative engine runs make_spec_step for EVERY
        # tick (it subsumes the mixed step: a draft-free decode row is the
        # T=1 case and a prefill chunk's first token is tgt[b, c-1]), so
        # spec adds one program family, not two
        make_step = make_mixed_step if spec is None else make_spec_step
        self._step_fn = make_step(cfg, self._gen, self._qctx)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request, rejecting impossible ones up front — a lazy
        admit-time failure would wedge the queue head and strand every
        queued request behind it. (Preemption re-queues bypass this:
        resume lengths are bounded by construction.)"""
        t = len(req.prompt)
        if t == 0:
            raise ValueError(
                f"request uid={req.uid}: empty prompt (there is no logits "
                f"position to sample a first token from)")
        if t > self.L - 1:
            raise ValueError(
                f"request uid={req.uid}: {t} prompt tokens do not fit a "
                f"max_len={self.L} {'row' if self.paged else 'slot'} "
                f"(>= 1 position must remain for decode)")
        if self.paged and self._blocks_for(t + 1) > self.num_blocks:
            raise ValueError(
                f"request uid={req.uid} needs {self._blocks_for(t + 1)} "
                f"blocks; the pool only has {self.num_blocks}")
        if req.n < 1:
            raise ValueError(f"request uid={req.uid}: n must be >= 1")
        if req.n > 1 and req.group is None:
            self._submit_group(req)
            return
        if req.arrival is None:
            req.arrival = self._arrival
            self._arrival += 1
        if req.submit_time is None:
            req.submit_time = self.now
        req.status = "queued"
        self.queue.append(req)

    def _submit_group(self, req: Request) -> None:
        """Expand ``Request(n=k)`` into k branch requests sharing the
        parent's uid. Branch i samples with seed ``base + i`` (base =
        the parent's seed, or its uid by default) — exactly the seeds k
        independent single requests would need to reproduce it, which is
        what the equivalence tests assert. The parent itself never
        queues; it lands in done/failed when its last branch does."""
        g = _SampleGroup(parent=req, n=req.n, prompt_len=len(req.prompt),
                         unshared=set(range(1, req.n)))
        base = req.seed if req.seed is not None else req.uid
        req.status = "queued"
        if req.submit_time is None:
            req.submit_time = self.now
        self._groups.append(g)
        for i in range(req.n):
            br = Request(uid=req.uid,
                         prompt=np.asarray(req.prompt, np.int32).copy(),
                         max_new_tokens=req.max_new_tokens,
                         priority=req.priority, seed=base + i,
                         deadline=req.deadline, timeout=req.timeout,
                         group=g, branch=i)
            g.branches.append(br)
            self.submit(br)

    def cancel(self, uid: int, status: str = "cancelled") -> bool:
        """Cancel a request by uid — queued, mid-prefill, or decoding —
        the same tick: its blocks are released immediately, queued prefill
        chunks are dropped with the cursor, and any generated tokens are
        delivered as a partial ``output``. A parallel-sampling request
        cancels ALL of its branches (they share the parent's uid).
        Returns False if the uid is not live (already finished or
        unknown)."""
        hit = False
        while True:
            found = False
            for j, req in enumerate(self.queue):
                if req.uid == uid:
                    self.queue.pop(j)
                    self._fail(req, status)
                    hit = found = True
                    break
            if found:
                continue
            for i, s in enumerate(self.slots):
                if s.req is not None and s.req.uid == uid:
                    self._evict(i, status)
                    hit = found = True
                    break
            if not found:
                return hit

    def _fail(self, req: Request, status: str,
              output: Optional[List[int]] = None) -> None:
        """Terminal non-success: stamp status/finish time, release any swap
        bytes, deliver a (possibly partial) output, move to ``failed``."""
        if req.swapped is not None:
            self._swap_bytes -= req.swapped.nbytes
            if output is None and req.swapped.generated:
                output = req.swapped.generated
            req.swapped = None
        if output is None and req.resume_generated:
            output = req.resume_generated
        req.output = np.asarray(output if output is not None else [],
                                np.int32)
        req.status = status
        req.finish_time = self.now
        self._land(req)

    def _land(self, req: Request) -> None:
        """Route a terminal request (status already stamped) to
        done/failed. Parallel-sampling branches aggregate into their
        parent instead of landing individually: the group's last terminal
        branch folds all branch outputs into ``parent.outputs`` and lands
        the PARENT once."""
        g = req.group
        if g is None:
            (self.done if req.status == "done" else self.failed).append(req)
            return
        g.results[req.branch] = req
        if not g.ready and req.branch == g.leader:
            # the prefilling leader died before publishing the prompt:
            # promote the next live branch so the group cannot deadlock
            # (the promoted leader is admissible and prefills cold)
            live = sorted(br.branch for br in g.branches
                          if br.branch not in g.results)
            if live:
                g.leader = live[0]
        if req.branch in g.unshared:
            # a branch that died before taking its snapshot turn
            g.unshared.discard(req.branch)
            self._maybe_drop_share(g)
        if len(g.results) == g.n:
            self._finalize_group(g)

    def _maybe_drop_share(self, g: _SampleGroup) -> None:
        """Release the group's prompt-block snapshot once every branch
        has taken (or terminally lost) its turn against it."""
        if g.shared and not g.unshared:
            self.allocator.release(g.shared)
            g.shared = []

    def _finalize_group(self, g: _SampleGroup) -> None:
        """All n branches are terminal: fold them into the parent.
        ``outputs`` keeps branch order; the parent is 'done' only if
        every branch finished, else it carries the first failing branch's
        status (individual branch outcomes stay readable per entry)."""
        if g.shared:
            self.allocator.release(g.shared)
            g.shared = []
        if g in self._groups:
            self._groups.remove(g)
        p = g.parent
        branches = [g.results[i] for i in range(g.n)]
        p.outputs = [br.output for br in branches]
        p.output = p.outputs[0]
        bad = [br.status for br in branches if br.status != "done"]
        p.status = "done" if not bad else bad[0]
        fts = [br.first_token_time for br in branches
               if br.first_token_time is not None]
        p.first_token_time = min(fts) if fts else None
        p.finish_time = self.now
        (self.done if p.status == "done" else self.failed).append(p)

    def _evict(self, i: int, status: str) -> None:
        """Terminally remove slot ``i``'s occupant (cancel/expire/shed):
        blocks released through the audited path, partial tokens kept."""
        s = self.slots[i]
        out = (s.prefill.resume if s.prefill is not None and s.prefill.resume
               else s.generated)
        self._release_blocks(i)
        self._fail(s.req, status, output=list(out))
        self.slots[i] = _Slot()

    def _release_blocks(self, i: int) -> None:
        """The ONE path blocks travel back to the free list (retire,
        preempt, cancel, shed all route here): frees the slot's blocks,
        clears its table row, marks the device mirror dirty. Keeping a
        single audited release point is what makes the allocator audit's
        no-leak/no-double-free invariant cheap to uphold."""
        s = self.slots[i]
        if not self.paged:
            return
        if s.blocks:
            self.allocator.release(s.blocks)
            s.blocks = []
        self.tables[i] = -1
        self._tables_dirty = True

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def _blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def _reset_row(self, i: int) -> None:
        """Reset slot ``i``'s batch-led device state (dense/ring KV rows,
        ring pos_ids, recurrent h/conv/cell) to the fresh template before a
        new occupant starts prefilling: stale ring position ids or
        recurrent state from the previous occupant would otherwise leak
        into the new request. Paged pool leaves are shared by all rows and
        left alone (newly allocated blocks are fully overwritten before any
        causally reachable read), and block tables stay host-owned."""
        def pick(path, batch_free, live_leaf, tmpl_leaf):
            if (path and path[-1] == _TABLE_KEY) or batch_free:
                return live_leaf
            # scanned caches stack layer groups in front: (G, B, ...)
            ax = 1 if path and path[0] == jax.tree_util.DictKey("groups") \
                else 0
            dst = (slice(None),) * ax + (i,)
            src = (slice(None),) * ax + (0,)
            return live_leaf.at[dst].set(tmpl_leaf[src])

        self.cache = jax.tree_util.tree_map_with_path(
            pick, self._batch_free, self.cache, self._row_template)

    def _admit_key(self, j: int):
        """Admission order: priority desc, then earliest deadline first
        among equals (deadline-free requests sort last within their
        priority), then arrival — so SLO-bearing traffic is both
        prioritized by tier and EDF-scheduled inside a tier."""
        r = self.queue[j]
        d = r.deadline if r.deadline is not None else float("inf")
        return (-r.priority, d, r.arrival)

    def _admit(self) -> None:
        """Bind queued requests to free slots in ``_admit_key`` order.
        Admission does NOT prefill — it resets the slot row and hands the
        prompt to the chunked tick — so its only gates are a free slot
        and, in paged mode, the free-block watermark (admission stops
        while ``free_blocks < admit_watermark``, keeping headroom for the
        rows already decoding instead of thrashing the pool). A swapped
        request instead restores its copied-out state into freshly
        allocated blocks (all-or-nothing); while its swap-in is denied it
        is deferred for the tick rather than blocking the queue head."""
        deferred: set = set()
        for i in self._free_slots():
            while True:
                cands = [j for j, r in enumerate(self.queue)
                         if id(r) not in deferred and self._admissible(r)]
                if not cands:
                    return
                if self.paged and \
                        self._avail() < self.admit_watermark:
                    return
                j = min(cands, key=self._admit_key)
                req = self.queue[j]
                if req.swapped is not None:
                    ok = self._try_swap_in(i, j)
                    if ok is None:       # degraded to recompute: re-pick
                        continue
                    if not ok:           # denied this tick: try next cand
                        deferred.add(id(req))
                        continue
                    break                # restored into slot i
                self.queue.pop(j)
                self._bind_slot(i, req)
                break

    def _admissible(self, r: Request) -> bool:
        """Sampling-group siblings wait for their leader's prefill (the
        shared prompt blocks) on engines that can share; on engines that
        cannot, the branches are plain independent requests."""
        g = r.group
        if g is None or not self._can_share:
            return True
        return g.ready or r.branch == g.leader

    def _bind_slot(self, i: int, req: Request) -> None:
        """Fresh (or recompute-resume) admission into slot ``i``."""
        resume = req.resume_generated
        req.resume_generated = None
        if resume:
            feed = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(resume[:-1], np.int32)])
        else:
            feed = np.asarray(req.prompt, np.int32)
        self._reset_row(i)
        key = np.asarray(jax.random.PRNGKey(
            req.seed if req.seed is not None else req.uid))
        self.slots[i] = _Slot(
            req=req, pos=0, generated=[], blocks=[], order=self._order,
            key=key,
            prefill=PrefillState(feed=feed,
                                 resume=list(resume) if resume else None))
        self._order += 1
        req.status = "running"
        if self.paged:
            self._attach_prefix(i, resumed=bool(resume))

    def _attach_prefix(self, i: int, resumed: bool) -> None:
        """Map the longest shareable prefix of slot ``i``'s feed onto
        EXISTING physical blocks, acquiring one reference per block, and
        advance the prefill cursor past the whole span — the engine runs
        zero prefill chunks for it. Two sources, tried in order:

          * sampling-group snapshot (fresh sibling admissions only): the
            leader's prompt blocks through token ``len(prompt) - 1``; the
            last prompt token is re-fed as a one-token prefill so the
            sibling's first sample sees the same logits the leader's did,
            and its first write (position len(prompt) - 1, inside the
            shared tail block) triggers copy-on-write;
          * prefix trie: full cached prompt blocks only (see
            ``serving.prefix_cache``), so a trie hit starts writing
            strictly AFTER the shared span and never copies.

        Shared KV reads are bitwise-equal to a cold prefill because KV
        bits (fp or int8 + per-token scale) are pure functions of (token,
        position) — the same invariance that already makes chunk size,
        slot assignment and preemption unobservable. Stale slots past the
        cursor inside a snapshot tail block are never read: causal
        masking hides positions > q, and position q itself is rewritten
        (identically) by the re-fed token's own scatter before use."""
        s = self.slots[i]
        req = s.req
        g = req.group
        blocks: List[int] = []
        start = 0
        if (self._can_share and g is not None and not resumed
                and req.branch in g.unshared and g.shared):
            blocks = list(g.shared)
            start = g.prompt_len - 1
            self.allocator.acquire(blocks)
            g.unshared.discard(req.branch)
            self._maybe_drop_share(g)
        elif self.prefix_cache is not None:
            blocks = self.prefix_cache.match(s.prefill.feed)
            start = len(blocks) * self.block_size
            if blocks:
                self.allocator.acquire(blocks)
        if not blocks or start <= 0:
            if blocks and start <= 0:    # 1-token prompt: nothing to skip
                self.allocator.release(blocks)
            return
        s.blocks = list(blocks)
        self.tables[i, :len(blocks)] = blocks
        self._tables_dirty = True
        s.pos = start
        s.prefill.done = start
        self.shared_admissions += 1
        self.shared_tokens += start

    # ---- swapped preemption ------------------------------------------
    def _swap_eligible(self, s: _Slot) -> bool:
        """The bytes-vs-recompute cost rule, reduced to a token threshold:
        swap-out cost is the row's live KV *bytes* — linear in cached
        tokens, a pure copy — while recompute-resume re-runs the model
        over every cached token (attention makes it superlinear, and even
        the linear term is a full forward per token, orders of magnitude
        more work per token than a memcpy). Both costs scale with the same
        token count, so 'swap when bytes beat recompute' is 'swap when
        the cached context is longer than a break-even token count'."""
        if self.swap_break_even_tokens is None or not self.paged:
            return False
        if s.pos < self.swap_break_even_tokens:
            return False
        if self.swap_pool_bytes is not None and \
                self._swap_bytes >= self.swap_pool_bytes:
            return False        # host swap pool full: fall back to recompute
        return True

    def _swap_out(self, i: int) -> SwappedState:
        """Copy slot ``i``'s live device state to host: its pool blocks
        (K/V and, for int8 KV, the per-slot scale vectors travel together
        — a block's scales are meaningless without it) in table order from
        every batch-free pool leaf, plus its row slice of every batch-led
        leaf (ring KV/pos_ids, recurrent states). The blocks themselves
        are released by the caller — a swapped request holds none."""
        s = self.slots[i]
        idx = jnp.asarray(s.blocks, jnp.int32)
        pool: Dict[Tuple, np.ndarray] = {}
        row: Dict[Tuple, np.ndarray] = {}

        def grab(path, batch_free, leaf):
            if path and path[-1] == _TABLE_KEY:
                return
            ax = 1 if path and path[0] == _GROUPS_KEY else 0
            if batch_free:
                pool[path] = np.asarray(jnp.take(leaf, idx, axis=ax))
            else:
                row[path] = np.asarray(leaf[(slice(None),) * ax + (i,)])

        jax.tree_util.tree_map_with_path(grab, self._batch_free, self.cache)
        st = s.prefill
        nbytes = sum(a.nbytes for a in pool.values()) \
            + sum(a.nbytes for a in row.values())
        return SwappedState(
            pool=pool, row=row, n_blocks=len(s.blocks), pos=s.pos,
            generated=list(s.generated),
            prefill=None if st is None else PrefillState(
                feed=st.feed, done=st.done,
                resume=list(st.resume) if st.resume else None),
            key=None if s.key is None else np.array(s.key),
            nbytes=nbytes)

    def _try_swap_in(self, i: int, j: int) -> Optional[bool]:
        """Attempt to restore queued request ``j`` into slot ``i``.
        Returns True on success, False when denied this tick (pool cannot
        hand out the blocks, or the chaos gate says no — bounded retry),
        and None when the retry budget is exhausted and the request
        degraded to recompute-resume (graceful degradation: recompute can
        always make incremental progress)."""
        req = self.queue[j]
        sw = req.swapped
        denied = self._swap_in_gate is not None and \
            not self._swap_in_gate(req)
        blocks = None if denied else self._alloc(sw.n_blocks)
        if blocks is None:
            sw.attempts += 1
            if sw.attempts > self.swap_retry_limit:
                self._drop_swap(req)
                return None
            return False
        self.queue.pop(j)
        idx = jnp.asarray(blocks, jnp.int32)

        def put(path, batch_free, leaf):
            if path and path[-1] == _TABLE_KEY:
                return leaf
            ax = 1 if path and path[0] == _GROUPS_KEY else 0
            if batch_free:
                sel = (slice(None),) * ax + (idx,)
                return leaf.at[sel].set(jnp.asarray(sw.pool[path],
                                                    leaf.dtype))
            sel = (slice(None),) * ax + (i,)
            return leaf.at[sel].set(jnp.asarray(sw.row[path], leaf.dtype))

        self.cache = jax.tree_util.tree_map_with_path(
            put, self._batch_free, self.cache)
        self.tables[i, :len(blocks)] = blocks
        self.tables[i, len(blocks):] = -1
        self._tables_dirty = True
        self.slots[i] = _Slot(req=req, pos=sw.pos,
                              generated=list(sw.generated),
                              blocks=list(blocks), order=self._order,
                              key=sw.key, prefill=sw.prefill)
        self._order += 1
        self._swap_bytes -= sw.nbytes
        req.swapped = None
        req.status = "running"
        return True

    def _drop_swap(self, req: Request) -> None:
        """Degrade a swapped request to recompute-resume (swap-in kept
        failing): reconstruct the recompute state from the host copy and
        release the swap bytes. Outputs stay exact — recompute-resume and
        swap-resume are bitwise equivalent by construction."""
        sw = req.swapped
        req.swapped = None
        self._swap_bytes -= sw.nbytes
        if sw.prefill is not None and sw.prefill.resume:
            req.resume_generated = list(sw.prefill.resume)
        elif sw.generated:
            req.resume_generated = list(sw.generated)

    def _preempt(self, i: int) -> None:
        """Evict slot ``i`` on pool pressure and re-queue it (the original
        arrival rank keeps it ahead of later equal-priority arrivals).
        Victims past the swap break-even copy their live state out to host
        (``SwappedState``: resume is a copy-in, no recompute); short
        victims stash their generated tokens for recompute-resume. Either
        way the blocks go back through the audited release path."""
        s = self.slots[i]
        req = s.req
        if self._swap_eligible(s):
            req.swapped = self._swap_out(i)
            self._swap_bytes += req.swapped.nbytes
            req.resume_generated = None
        elif s.prefill is not None and s.prefill.resume:
            req.resume_generated = list(s.prefill.resume)
        else:
            req.resume_generated = list(s.generated)
        self._release_blocks(i)
        req.status = "queued"
        self.queue.append(req)
        self.slots[i] = _Slot()

    def preempt_slot(self, i: int) -> None:
        """Force-preempt live slot ``i`` (chaos storms, tests): exactly the
        pool-pressure eviction path, including the swap-vs-recompute
        choice."""
        if self.slots[i].req is None:
            raise ValueError(f"slot {i} is not occupied")
        self._preempt(i)

    # ------------------------------------------------------------------
    def _avail(self) -> int:
        """Blocks an allocation could obtain right now: the free list
        plus whatever LRU trie eviction could release. Admission and
        growth gate on this, not raw ``available`` — the prefix cache
        must never block a live row."""
        n = self.allocator.available
        if self.prefix_cache is not None:
            n += self.prefix_cache.evictable()
        return n

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Allocate through LRU trie eviction. Eviction runs only on a
        GENUINE shortage (``available < n``): a transient fault denial
        while free blocks exist must NOT flush the cache — the denial
        path still returns None and the caller's fault handling engages."""
        if n <= 0:
            return []
        if self.prefix_cache is not None and self.allocator.available < n:
            self.prefix_cache.evict(n - self.allocator.available)
        return self.allocator.alloc(n)

    def _copy_blocks(self, pairs: List[Tuple[int, int]]) -> None:
        """Flush copy-on-write block copies device-side, BEFORE any of
        this tick's forward writes land. Pairs are pow-2 padded by
        repeating the first pair (a duplicate copy writes the same bytes
        twice — idempotent), so the jitted copy compiles at most
        O(log B) times and the decode tick's own compile budget is
        untouched."""
        self.cow_copies += len(pairs)
        n = _bucket(len(pairs))
        pairs = pairs + [pairs[0]] * (n - len(pairs))
        src = jnp.asarray([p[0] for p in pairs], jnp.int32)
        dst = jnp.asarray([p[1] for p in pairs], jnp.int32)
        self.cache = self._cow_fn(self.cache, src, dst)

    def _grow_blocks(self, i: int, n_tokens: int) -> int:
        """Paged: try to grow slot ``i``'s block list to cover its next
        ``n_tokens`` writes; allocates as many of the missing blocks as the
        pool can give. Returns how many of the ``n_tokens`` writes are now
        covered (possibly 0).

        Copy-on-write: if the block the next write lands in is still
        referenced by another owner (prefix trie, sampling-group snapshot
        or a sibling row), the row's entry is remapped to a fresh block
        and the content copied device-side first. Only the entry holding
        ``pos`` can ever be shared — shared spans sit strictly before the
        cursor and growth appends fresh blocks — so one check suffices."""
        s = self.slots[i]
        e = s.pos // self.block_size
        if e < len(s.blocks) and self.allocator.refcount(s.blocks[e]) > 1:
            got = self._alloc(1)
            if got is None:
                if self.allocator.available >= 1:
                    # denied despite a free block: transient fault, see below
                    self._alloc_fault = True
                return 0
            old, new = s.blocks[e], got[0]
            # copy first, then hand back our reference: the copy is
            # flushed immediately so no later device write (swap-in
            # restore, this tick's forward) can race it
            self._copy_blocks([(old, new)])
            self.allocator.release([old])
            s.blocks[e] = new
            self.tables[i, e] = new
            self._tables_dirty = True
        need = self._blocks_for(s.pos + n_tokens) - len(s.blocks)
        if need > 0:
            take = min(need, self._avail())
            got = self._alloc(take) if take > 0 else None
            if take > 0 and got is None:
                # the allocator denied a request its own 'available' said
                # it could serve: a transient fault (chaos injection), not
                # pool pressure — flag it so _plan stalls instead of
                # preempting (freeing blocks cannot cure a denial)
                self._alloc_fault = True
            if got:
                self.tables[i, len(s.blocks):len(s.blocks) + len(got)] = got
                s.blocks.extend(got)
                self._tables_dirty = True
        return max(0, min(n_tokens, len(s.blocks) * self.block_size - s.pos))

    def _plan(self, want_decode: bool, want_prefill: bool,
              allow_preempt: bool) -> np.ndarray:
        """Carve this sub-step's per-row token counts against the budget,
        allocating paged blocks as needed. Decode rows come first (1 token
        each — inter-token latency is the knob the budget must never
        starve), then prefill chunks: earliest deadline first among
        deadline-bearing rows, admission order after them, against the
        smaller of the remaining budget and ``prefill_budget`` (the p99
        guard: a burst of admissions cannot inflate the tick past the
        prefill cap). If the pool is exhausted and NO row can advance,
        preempt the most recently admitted stalled row and retry — unless
        the failure was a transient allocator fault, which stalls the tick
        instead (preemption cannot cure a denial). A single stalled row
        holding the whole pool means the pool is simply too small for the
        request: raise, or shed it under ``on_pool_exhausted='shed'``."""
        while True:
            counts = np.zeros(self.B, np.int32)
            stalled: List[int] = []
            budget = self.token_budget
            pleft = self.prefill_budget if self.prefill_budget is not None \
                else self.token_budget
            self._tick_drafts = {}
            if want_decode:
                for i, s in enumerate(self.slots):
                    if s.req is None or s.prefill is not None:
                        continue
                    drafts: List[int] = []
                    if self.spec is not None:
                        # per-row draft length: the SpecConfig cap, then
                        # the cache row's write bounds (k+1 writes at
                        # pos..pos+k must stay < L-1 so the row can still
                        # retire cleanly), then the tokens the request
                        # can still USE (accepting past max_new_tokens
                        # is wasted verification), then leftover budget
                        # (the base decode token stays budget-exempt,
                        # like the non-speculative tick)
                        k_cap = min(self.spec.k,
                                    self.L - 2 - s.pos,
                                    s.req.max_new_tokens
                                    - len(s.generated) - 1,
                                    budget - 1)
                        if k_cap > 0:
                            drafts = self._drafter.propose(
                                s.req.prompt, s.generated, k_cap)
                    c = 1 + len(drafts)
                    if self.paged:
                        # one tick may cross several block boundaries;
                        # a short grant truncates the draft instead of
                        # stalling the row
                        c = self._grow_blocks(i, c)
                        if c < 1:
                            stalled.append(i)
                            continue
                        drafts = drafts[:c - 1]
                    counts[i] = c
                    budget -= c
                    if drafts:
                        self._tick_drafts[i] = drafts
            if want_prefill:
                def edf(i):
                    s = self.slots[i]
                    d = s.req.deadline if s.req.deadline is not None \
                        else float("inf")
                    return (d, s.order)
                pre = sorted(
                    (i for i, s in enumerate(self.slots)
                     if s.req is not None and s.prefill is not None),
                    key=edf)
                uniform_c = None
                if self._uniform and pre:
                    uniform_c = min(min(self.slots[i].prefill.remaining
                                        for i in pre),
                                    self._chunk_cap, max(budget, 0),
                                    max(pleft, 0))
                for i in pre:
                    if budget <= 0 or pleft <= 0:
                        break
                    s = self.slots[i]
                    if uniform_c is not None:
                        if uniform_c > min(budget, pleft):
                            break
                        c = uniform_c
                    else:
                        c = min(s.prefill.remaining, self._chunk_cap,
                                budget, pleft)
                    if c > 0 and self.paged:
                        c = self._grow_blocks(i, c)
                        if self._uniform and 0 < c < uniform_c:
                            # a short chunk would make the step ragged;
                            # recurrent rows sit this tick out instead
                            c = 0
                    if c <= 0:
                        stalled.append(i)
                        continue
                    counts[i] = c
                    budget -= c
                    pleft -= c
            if counts.any() or not stalled:
                return counts
            if not allow_preempt:
                return counts
            if self._alloc_fault:
                # transient fault: stall the tick and retry next step();
                # step() bounds the streak with priority-ordered shedding
                return counts
            occupied = sum(s.req is not None for s in self.slots)
            if occupied == 1:
                if self._drop_group_shares():
                    continue      # snapshot refs released: retry the plan
                s = self.slots[stalled[0]]
                if self.on_pool_exhausted == "shed":
                    self._evict(stalled[0], "shed")
                    continue
                raise RuntimeError(
                    f"block pool too small: request uid={s.req.uid} holds "
                    f"{len(s.blocks)}/{self.num_blocks} blocks and still "
                    f"needs more; increase num_blocks")
            self._preempt(max(stalled, key=lambda i: self.slots[i].order))

    def _drop_group_shares(self) -> bool:
        """Last-resort pool relief when a single row holds everything it
        can get and still stalls: release every sampling group's prompt
        snapshot (branches that have not taken their turn will re-prefill
        via the trie or from scratch — slower, never incorrect). Returns
        True if anything was released."""
        hit = False
        for g in self._groups:
            if g.shared:
                self.allocator.release(g.shared)
                g.shared = []
                g.unshared.clear()
                hit = True
        return hit

    def _live_width(self) -> Optional[int]:
        """Static block-table read width for this tick: the max blocks any
        occupied slot holds, rounded up to a power of two (so at most
        log2(W)+1 distinct jit specializations exist). Allocation is
        prefix-dense — tables fill from entry 0 — so every live token of
        every row sits inside the first ``live_width`` entries and slicing
        the READ path there is exact. Returns None in dense mode."""
        if not self.paged:
            return None
        held = max((len(s.blocks) for s in self.slots if s.req is not None),
                   default=1)
        return min(_bucket(held), self.tables.shape[1])

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s.req is None or s.prefill is not None:
                continue
            out_len = len(s.generated)
            hit_eos = self.eos_id is not None and s.generated and \
                s.generated[-1] == self.eos_id
            if out_len >= s.req.max_new_tokens or hit_eos or s.pos >= self.L - 1:
                s.req.output = np.asarray(s.generated, np.int32)
                s.req.status = "done"
                s.req.finish_time = self.now
                self._release_blocks(i)
                self._land(s.req)
                self.slots[i] = _Slot()

    def _substep(self, want_decode: bool = True, want_prefill: bool = True,
                 allow_preempt: bool = True) -> int:
        """Plan, assemble and run ONE fused forward; apply its results to
        the slots. Returns the number of rows that advanced."""
        counts = self._plan(want_decode, want_prefill, allow_preempt)
        run = np.flatnonzero(counts)
        if run.size == 0:
            return 0
        self.last_counts = counts.copy()
        # recurrent rows would feed any padding tail into their recurrence
        # (no per-token write index to mask), so uniform mode uses the
        # exact chunk length — one compile per distinct prompt-chunk size,
        # the same specialization behavior as a one-shot prefill engine
        # repro: ignore[R002] uniform recurrent rows need the exact chunk length
        t_step = int(counts.max()) if self._uniform \
            else _bucket(int(counts.max()))
        tokens = np.zeros((self.B, t_step), np.int32)
        pos = np.zeros((self.B,), np.int32)
        final = {}
        for i in run:
            s = self.slots[i]
            c = int(counts[i])
            pos[i] = s.pos
            if s.prefill is None:
                tokens[i, 0] = s.generated[-1] if s.generated else 0
                drafts = self._tick_drafts.get(i)
                if drafts:
                    tokens[i, 1:c] = drafts
            else:
                st = s.prefill
                tokens[i, :c] = st.feed[st.done:st.done + c]
                final[i] = st.done + c == len(st.feed)
        keys = np.stack([s.key if s.key is not None
                         else np.zeros((2,), np.uint32) for s in self.slots])
        if self.paged and self._tables_dirty:
            self.cache = _with_tables(self.cache, jnp.asarray(self.tables))
            self._tables_dirty = False
        live_widths = jnp.asarray([len(s.blocks) for s in self.slots],
                                  jnp.int32) if self.paged else None
        # the step returns its block tables unchanged, so in steady state
        # (no admissions/retirements) the paged tick is as cheap as the
        # dense one: no table upload, no tree surgery
        nxt, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos),
            jnp.asarray(counts), jnp.asarray(keys),
            self._live_width(), live_widths)
        nt = np.asarray(nxt)
        spec_on = self.spec is not None
        self.last_tick_tokens += int(counts.sum())
        for i in run:
            s = self.slots[i]
            c = int(counts[i])
            if s.prefill is None:
                if spec_on:
                    self._apply_spec_decode(i, nt[i], c)
                else:
                    s.generated.append(int(nt[i]))
                    s.pos += 1
                    self.last_tick_new_tokens += 1
            else:
                st = s.prefill
                st.done += c
                s.pos += c
                if final[i]:
                    # chunk-aware sampling: only the final chunk's last-token
                    # logits produce a token — the request's first generated
                    # token at position len(feed), drawn under the same
                    # position-keyed rule as every decode tick. A resumed
                    # request restores its stashed continuation instead.
                    # (A spec step returns the (T,) target row; entry c-1
                    # is exactly the mixed step's last-token sample.)
                    first = int(nt[i, c - 1]) if spec_on else int(nt[i])
                    s.generated = list(st.resume) if st.resume else [first]
                    s.prefill = None
                    if not st.resume:
                        self.last_tick_new_tokens += 1
                    self._on_prefill_done(i)
            if s.generated and s.req.first_token_time is None:
                s.req.first_token_time = self.now
        return int(run.size)

    def _apply_spec_decode(self, i: int, tgt: np.ndarray, c: int) -> None:
        """Verify slot ``i``'s drafts against the (T,) target row of the
        speculative tick and bank 1..c tokens: the longest draft prefix
        with ``draft[j] == tgt[j]`` plus the bonus token ``tgt[n_acc]``
        (always valid — it was sampled conditioned only on the accepted
        prefix). EOS / max_new_tokens truncate the banked run, in which
        case the row retires this very tick and its over-written cache
        tail is never read. ``pos`` advances by the banked count, so
        rejected drafts' cache entries sit at positions >= the new pos:
        causally invisible to every read, and overwritten (with identical
        bits) by the row's own future writes before pos passes them."""
        s = self.slots[i]
        drafts = self._tick_drafts.pop(i, [])
        n_acc = 0
        while n_acc < len(drafts) and drafts[n_acc] == int(tgt[n_acc]):
            n_acc += 1
        self.spec_drafted += len(drafts)
        self.spec_accepted += n_acc
        banked = drafts[:n_acc] + [int(tgt[n_acc])]
        room = s.req.max_new_tokens - len(s.generated)
        kept: List[int] = []
        for tok in banked:
            kept.append(tok)
            if self.eos_id is not None and tok == self.eos_id:
                break
            if len(kept) >= room:
                break
        s.generated.extend(kept)
        s.pos += len(kept)
        self.last_tick_new_tokens += len(kept)

    def _on_prefill_done(self, i: int) -> None:
        """Prefill-completion hooks for slot ``i``:

          * publish the row's FULL prompt blocks into the prefix trie
            (insert dedupes, so a trie-hit row republishing its matched
            span is a no-op and only genuinely new blocks gain a ref);
          * for a sampling-group leader, snapshot the blocks covering the
            prompt (one extra ref each) and flip ``ready`` — the siblings
            become admissible against the snapshot."""
        s = self.slots[i]
        req = s.req
        plen = len(req.prompt)
        if self.prefix_cache is not None:
            n_full = plen // self.block_size
            if n_full > 0:
                prompt = np.asarray(req.prompt, np.int32)
                self.prefix_cache.insert(prompt[:n_full * self.block_size],
                                         s.blocks[:n_full])
        g = req.group
        if (g is not None and self._can_share and not g.ready
                and req.branch == g.leader):
            g.ready = True
            if g.unshared:
                shared = s.blocks[:self._blocks_for(plen)]
                self.allocator.acquire(shared)
                g.shared = list(shared)

    # ---- SLO enforcement / degradation -------------------------------
    def _min_ticks_left(self, req: Request) -> int:
        """Optimistic lower bound on ticks to finish a QUEUED request:
        prefill chunks at the full chunk cap plus one decode tick per
        remaining token. Used only to shed provably-late requests, so it
        must underestimate, never overestimate."""
        if req.swapped is not None:
            sw = req.swapped
            feed_left = sw.prefill.remaining if sw.prefill is not None else 0
            dec = max(0, req.max_new_tokens - len(sw.generated))
        else:
            resume = req.resume_generated or []
            feed_left = len(req.prompt) + max(0, len(resume) - 1)
            dec = max(0, req.max_new_tokens - len(resume))
        cap = min(self._chunk_cap,
                  self.prefill_budget or self.token_budget)
        if self.spec is not None:
            # a speculative tick can bank up to k+1 decode tokens; the
            # bound must stay OPTIMISTIC (shedding on an overestimate
            # would drop feasible requests), so assume full acceptance
            dec = -(-dec // (self.spec.k + 1))
        return -(-feed_left // max(cap, 1)) + dec

    def _enforce_slos(self) -> None:
        """Same-tick cancellation of requests past their deadline or
        timeout — queued, mid-prefill or decoding — plus early shedding of
        queued requests whose optimistic remaining work already overruns
        their deadline (only once a tick-cost estimate exists)."""
        now = self.now
        for req in list(self.queue):
            late = req.deadline is not None and now > req.deadline
            timed = req.timeout is not None and req.submit_time is not None \
                and now - req.submit_time > req.timeout
            if late or timed:
                self.queue.remove(req)
                self._fail(req, "expired" if late else "timeout")
            elif (self.shed_infeasible and req.deadline is not None
                  and self._tick_ewma is not None
                  and now + self._min_ticks_left(req) * self._tick_ewma
                  > req.deadline):
                self.queue.remove(req)
                self._fail(req, "shed")
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            req = s.req
            late = req.deadline is not None and now > req.deadline
            timed = req.timeout is not None and req.submit_time is not None \
                and now - req.submit_time > req.timeout
            if late or timed:
                self._evict(i, "expired" if late else "timeout")

    def _shed_one(self) -> None:
        """Persistent-fault degradation: drop exactly ONE victim, in
        strict priority order — lowest priority first, newest arrival
        among equals — preferring queued requests over running rows (a
        running row may still drain what it holds)."""
        if self.queue:
            j = min(range(len(self.queue)),
                    key=lambda j: (self.queue[j].priority,
                                   -(self.queue[j].arrival or 0)))
            req = self.queue.pop(j)
            self._fail(req, "shed")
            return
        live = [i for i, s in enumerate(self.slots) if s.req is not None]
        if live:
            i = min(live, key=lambda i: (self.slots[i].req.priority,
                                         -self.slots[i].order))
            self._evict(i, "shed")

    def audit(self) -> None:
        """Block-accounting invariant, refcount edition: every physical
        block's refcount equals its OWNER COUNT summed across slot block
        tables, the prefix trie, and sampling-group snapshots — and free
        blocks are exactly the zero-ref ones. Plus: host tables mirror
        slot state (a block appears at most once per row), the trie owns
        each of its blocks once, swapped requests hold zero device
        blocks, swap-byte accounting balances. Raises
        ``AllocatorAuditError`` on any violation — the chaos harness
        calls this after every step, and ``debug_audit=True`` makes the
        engine self-check every tick."""
        if not self.paged:
            return
        owners: Dict[int, int] = {}
        for i, s in enumerate(self.slots):
            if s.req is None:
                if s.blocks:
                    raise AllocatorAuditError(
                        f"empty slot {i} holds blocks {s.blocks}")
                if not (self.tables[i] == -1).all():
                    raise AllocatorAuditError(
                        f"empty slot {i} has stale table entries")
                continue
            row_seen = set()
            for b in s.blocks:
                if b in row_seen:
                    raise AllocatorAuditError(
                        f"slot {i} maps block {b} twice")
                row_seen.add(b)
                owners[b] = owners.get(b, 0) + 1
            w = len(s.blocks)
            if list(self.tables[i, :w]) != s.blocks or \
                    not (self.tables[i, w:] == -1).all():
                raise AllocatorAuditError(
                    f"slot {i} table row {self.tables[i].tolist()} does "
                    f"not mirror its blocks {s.blocks}")
        if self.prefix_cache is not None:
            cached = self.prefix_cache.cached_blocks()
            if len(cached) != len(set(cached)):
                raise AllocatorAuditError(
                    "prefix trie owns a block through two nodes")
            for b in cached:
                owners[b] = owners.get(b, 0) + 1
        for g in self._groups:
            for b in g.shared:
                owners[b] = owners.get(b, 0) + 1
        free = self.allocator.free_list()
        if len(free) != len(set(free)):
            raise AllocatorAuditError("free list repeats a block id")
        free_set = set(free)
        for b in range(self.num_blocks):
            rc = self.allocator.refcount(b)
            own = owners.get(b, 0)
            if rc != own:
                raise AllocatorAuditError(
                    f"block {b}: refcount {rc} != owner count {own} "
                    f"(slots + trie + sampling groups)")
            if (rc == 0) != (b in free_set):
                raise AllocatorAuditError(
                    f"block {b}: refcount {rc} inconsistent with free-"
                    f"list membership {b in free_set}")
        swap_bytes = sum(r.swapped.nbytes for r in self.queue
                         if r.swapped is not None)
        if swap_bytes != self._swap_bytes:
            raise AllocatorAuditError(
                f"swap byte accounting broken: held={self._swap_bytes} "
                f"but queued swaps sum to {swap_bytes}")

    def step(self, now: Optional[float] = None) -> int:
        """One scheduler tick: enforce SLOs, retire, admit, run the mixed
        token-budget step (or the split decode/uniform-prefill sub-steps
        for recurrent configs), retire again. ``now`` is the caller's
        clock (virtual or wall — deadlines/timeouts are compared against
        it); omitted, it advances an internal tick counter by 1. Returns
        the number of rows advanced (0 = stalled or idle, never an
        exception under transient faults)."""
        now = self.now + 1.0 if now is None else float(now)
        dt = now - self.now
        if dt > 0 and self._prev_advanced:
            # per-tick cost estimate for infeasibility shedding; only
            # ticks that did work count (idle clock jumps would bloat it)
            self._tick_ewma = dt if self._tick_ewma is None \
                else 0.8 * self._tick_ewma + 0.2 * dt
        self.now = now
        self._alloc_fault = False
        self.last_tick_tokens = 0
        self.last_tick_new_tokens = 0
        self._retire()
        self._enforce_slos()
        self._admit()
        if self._uniform:
            has_pre = any(s.req is not None and s.prefill is not None
                          for s in self.slots)
            n = self._substep(want_prefill=False,
                              allow_preempt=not has_pre)
            if has_pre:
                n += self._substep(want_decode=False,
                                   allow_preempt=(n == 0))
        else:
            n = self._substep()
        self._retire()
        self._prev_advanced = n > 0
        if self._alloc_fault and n == 0:
            self._fault_streak += 1
            if self._fault_streak > self.fault_shed_after:
                # the fault is persistent: degrade by policy instead of
                # queueing unboundedly — one victim per tick, lowest
                # priority first
                self._shed_one()
        elif not self._alloc_fault:
            self._fault_streak = 0
        if self.debug_audit:
            self.audit()
        return n

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(s.req for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
