"""Token-budget continuous-batching scheduler over one fused mixed step.

Real serving stacks (vLLM/JetStream/Sarathi-style) do not run prefill and
decode as separate phases: every engine tick assembles ONE forward pass of
up to ``token_budget`` tokens in which decoding rows contribute 1 token
each and admitted-but-unfinished prompts contribute a prefill *chunk* —
several chunks from different requests batched together, interleaved with
the decode rows. This module is the jax-native equivalent:

  * a fixed-shape slot pool (batch B rows) holds all request state;
  * each tick carves chunks (``PrefillState`` cursors + budget accounting),
    left-aligns every row's contribution into a ``(B, T)`` token block
    (T = the bucketed max contribution), and runs one jitted
    ``step_rows`` forward: per-row ``pos`` vectors place each row at its
    own absolute position, a per-token ``active`` mask drops the padding
    tail's cache writes, and only each row's LAST real token's logits are
    consumed (chunk-aware sampling — a non-final chunk discards them, a
    final chunk samples the request's first token, a decode row its next);
  * there is no separate admission prefill: admission just binds a slot,
    resets its row state, and lets the tick stream the prompt in — so
    decode rows keep advancing while prompts prefill, and a prompt longer
    than a ``local_attn`` window is admissible (chunks are capped at the
    window; the ring read path handles multi-token chunks — the seed's
    one-shot ring prefill limit is gone).

Admission is (priority, arrival)-ordered — ``Request.priority`` (higher
first), FIFO among equals, so equal-priority traffic cannot starve — and
gated by a free-block *watermark* in paged mode (``admit_watermark``:
admit only while ``free_blocks >= watermark``), replacing the seed's bare
FIFO head-of-line.

Two KV-cache backends, selected by ``paged``:

  * dense (default) — every row reserves ``max_len`` KV positions up front
    (``init_cache``). Admission is gated by free *slots*; memory scales with
    B * max_len regardless of how long requests actually are.
  * paged — a global block pool of ``num_blocks`` blocks of ``block_size``
    tokens per layer plus per-row block tables (``init_paged_cache``).
    ``BlockAllocator`` is the host-side free list; blocks are allocated as
    chunks and decode writes land in them (a chunk shrinks to the blocks it
    can get — partial prefill progress is fine) and freed at retirement.
    When the pool is exhausted and NO row can advance, the most recently
    admitted stalled row is preempted vLLM-style: its blocks are freed and
    the request is re-queued (keeping its original arrival rank) for
    recompute-resume. The resume is just a longer prompt re-entering the
    SAME chunked-prefill path — greedy decode, and position-keyed sampling
    where the token at position p is drawn with ``fold_in(request_seed,
    p)``, make the resumed continuation exact, and chunking makes rows past
    a ``local_attn`` window preemptable too (the seed had to refuse them).

The decode tick samples with ``GenerateConfig`` parity: pass ``gen=`` for
temperature/top-k (greedy by default) and ``Request.seed`` for per-request
reproducibility. In paged mode each tick passes a bucketed *live width* —
the max blocks any row holds, rounded to a power of two — as a static
argument plus a per-row live-width vector, so the paged attention read
(Pallas kernel on TPU, XLA gather elsewhere; see
``core.attention.paged_attention``) only visits the allocated block-table
prefix and each row's read is masked at its own block count.

Models with recurrent blocks (griffin/xlstm) cannot express ragged rows
(a recurrence has no per-token write index to mask), so for those configs
the engine splits each tick into a decode sub-step and a uniform-length
prefill sub-step instead of one mixed ragged step — still chunked, still
non-stalling, just not interleaved within a single forward.

The per-row ``pos`` vector / masked per-token scatter contract the step
relies on is documented in ``repro.models.transformer.model_apply`` and
``repro.core.attention``; the architecture narrative lives in
``docs/serving.md``.

Slot and block bookkeeping is host-side python (cheap, O(B) per step); all
tensor work stays jitted with static shapes — (T, live_width) pairs are
bucketed to powers of two so at most O(log(budget) * log(W)) step
specializations exist.

INT8 serving (the paper's payoff, live): ``qconfig=`` turns the tick into
a W8A8 forward — activation ranges are PTQ-calibrated ONCE at engine
construction against a few synthetic batches (``quant.ptq.calibrate``),
the matmul weights are pre-quantized onto the params tree
(``quant.int8_weights.attach_int8_weights``) and every linear routes
through the int8 MXU kernel with those static ranges (see
``nn.layers.linear_apply``); the calibrated context is captured by the
jitted step as closure constants, so the tick compiles exactly like the
fp one. ``kv_int8=`` (default: on whenever ``qconfig`` is given with
``paged=True``) stores the paged KV pools as int8 with per-slot scale
vectors — quantize fused into the cache scatter, dequant into both paged
read backends (``init_paged_cache(kv_int8=True)``). KV block memory drops
~3.5x for typical head shapes, so an equal-byte pool admits proportionally
more concurrent rows; serving stays bitwise invariant to chunking, slot
assignment and preemption-resume because each token is quantized exactly
once at write (see ``quant.kv_cache``). ``kv_int8=True`` alone (no
``qconfig``) is allowed: fp matmuls over a quantized cache.

Robustness layer (SLO-aware scheduling, swapped preemption, degradation —
see ``docs/serving.md`` "Traffic, SLOs, and failure handling"):

  * ``step(now=...)`` threads a caller-owned clock (the open-loop workload
    harness in ``serving.workload`` drives a deterministic virtual clock;
    ``now`` defaults to an internal tick counter). ``Request`` grows
    ``deadline`` (absolute, same clock) and ``timeout`` (relative to
    submission): expired/timed-out requests are cancelled the same tick —
    queued, mid-prefill or decoding — with their blocks released, and land
    in ``self.failed`` with a status string. Queued requests whose minimum
    remaining work provably cannot meet their deadline are shed early
    (``shed_infeasible``), and deadline-bearing requests are admitted and
    prefill-carved earliest-deadline-first within a priority level.
    ``prefill_budget`` caps the prefill share of each tick's token budget
    so a burst of arrivals cannot inflate decode-tick p99.
  * swapped preemption: with ``swap_break_even_tokens`` set, a preemption
    victim whose cached context is long copies its live pool blocks (and
    int8 scale vectors) plus its batch-led row state out to host memory
    (``SwappedState``) and copies them back in on resume — bit-exact, no
    recompute. Short victims keep the recompute-resume path: swap cost
    scales with the row's KV *bytes* (linear in tokens) while recompute
    re-runs the model over all cached tokens (much more expensive per
    token), so the bytes-vs-recompute rule reduces to a token threshold.
    Swap-in is all-or-nothing; after ``swap_retry_limit`` failed attempts
    (pool pressure or an injected denial) the request degrades to
    recompute-resume, which can always make incremental progress.
  * fault tolerance: every block release goes through one audited
    ``_release_blocks`` helper, ``BlockAllocator.free`` rejects double
    frees and foreign ids, and ``audit()`` checks the full invariant
    (every block exactly one of free / owned-by-live-row; tables mirror
    slot state; swapped requests hold zero device blocks) —
    ``debug_audit=True`` runs it after every tick. A spurious allocation
    failure (the allocator denies despite free blocks — ``serving.chaos``
    injects these) is treated as transient: the tick stalls and retries
    instead of preempting; once the fault persists past
    ``fault_shed_after`` ticks the engine degrades by policy, shedding
    exactly one victim per tick in strict priority order (lowest first,
    newest arrival among equals). ``on_pool_exhausted="shed"`` converts
    the one remaining hard failure (a single request larger than the whole
    pool) into a shed as well.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    ModelConfig,
    init_cache,
    init_paged_cache,
    model_apply,
)
from repro.quant.int8_weights import attach_int8_weights
from repro.quant.ptq import calibrate
from repro.quant.qconfig import NO_QUANT, QConfig
from repro.serving.decode import GenerateConfig, make_mixed_step

Array = jax.Array

_TABLE_KEY = jax.tree_util.DictKey("block_table")
_GROUPS_KEY = jax.tree_util.DictKey("groups")
_RECURRENT_KINDS = ("griffin", "mlstm", "slstm")


class AllocatorAuditError(RuntimeError):
    """A block-accounting invariant was violated (leak, double free,
    foreign id, stale table mirror). Raised by ``BlockAllocator.free`` and
    ``ContinuousBatcher.audit`` — the chaos harness asserts this never
    fires under any fault plan."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 32
    # admission priority: HIGHER is served first; FIFO (arrival order)
    # among equal priorities, so equal-priority traffic cannot starve
    priority: int = 0
    # per-request sampling seed (used when the batcher's GenerateConfig has
    # temperature > 0); None derives a deterministic default from uid
    seed: Optional[int] = None
    # --- SLOs (see step(now=...): all times share the caller's clock) ---
    # absolute completion deadline: past it the request is cancelled
    # ("expired") and its tokens no longer count toward goodput; queued
    # requests that provably cannot meet it are shed early
    deadline: Optional[float] = None
    # relative cap on time since submission ("timeout" when exceeded)
    timeout: Optional[float] = None
    # filled by the scheduler
    output: Optional[np.ndarray] = None
    # lifecycle: queued -> running -> done | cancelled | expired | timeout
    # | shed (failed statuses land the request in batcher.failed)
    status: str = "queued"
    submit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # internal: host-side copy-out of a swap-preempted row (swap-resume)
    swapped: Optional["SwappedState"] = None
    # internal: tokens generated before a preemption (recompute-resume state)
    resume_generated: Optional[List[int]] = None
    # internal: submission sequence number (admission tie-break; a preempted
    # request keeps its original arrival, so re-queueing cannot demote it
    # behind later arrivals of the same priority)
    arrival: Optional[int] = None


@dataclasses.dataclass
class PrefillState:
    """Chunked-prefill cursor of one admitted request.

    ``feed`` is everything that must stream through the model before the
    request can decode: the prompt, plus — for a recompute-resume after
    preemption — all but the last of its previously generated tokens (the
    last one becomes the first decode input again). ``done`` tokens of it
    are already written to the cache; each tick the scheduler carves the
    next chunk ``feed[done:done+c]`` against the token budget."""
    feed: np.ndarray                 # (T,) int32
    done: int = 0
    # recompute-resume: the previously generated tokens, restored verbatim
    # when the prefill completes (the final chunk's sample is discarded —
    # position-keyed sampling would reproduce it exactly anyway)
    resume: Optional[List[int]] = None

    @property
    def remaining(self) -> int:
        return len(self.feed) - self.done


@dataclasses.dataclass
class SwappedState:
    """Host-side copy-out of a swap-preempted row's live device state.

    ``pool`` maps cache-leaf paths of the batch-free pool leaves (the K/V
    block pools and, for int8 KV, their per-slot scale vectors) to the
    victim's block rows in block-table order; ``row`` maps batch-led leaf
    paths (ring KV / pos_ids, recurrent h/conv/cell) to the victim's row
    slice. Together with the slot bookkeeping below, a swap-in restores
    the row bit-exactly into freshly allocated blocks — no recompute.
    The copied blocks themselves are FREED at swap-out: a swapped request
    holds zero device blocks (the allocator audit checks this)."""
    pool: Dict[Tuple, np.ndarray]
    row: Dict[Tuple, np.ndarray]
    n_blocks: int
    pos: int
    generated: List[int]
    prefill: Optional[PrefillState]
    key: Optional[np.ndarray]
    nbytes: int
    attempts: int = 0        # failed swap-in tries (bounded retry)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                     # next cache position (= tokens written)
    generated: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)  # paged only
    order: int = 0                   # admission sequence number
    key: Optional[np.ndarray] = None  # (2,) uint32 request PRNG key
    prefill: Optional[PrefillState] = None   # None once fully prefilled


class BlockAllocator:
    """Host-side free list over the global KV block pool.

    Physical block ids are plain ints in [0, num_blocks); the pool tensors
    live on device, only the *mapping* is host state. A single ``alloc``
    call is all-or-nothing, but callers may take less than they ultimately
    want: ``_grow_blocks`` claims ``min(need, available)`` so a prefill
    chunk shrinks to partial progress instead of stalling — a row CAN hold
    blocks for writes it has not made yet (they are used on a later tick,
    or returned wholesale at preemption/retirement)."""

    def __init__(self, num_blocks: int) -> None:
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self._free_set = set(self._free)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks, or None (and no side effect) if not enough."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(got)
        return got

    def free(self, blocks: List[int]) -> None:
        """Return blocks to the free list. Double frees and foreign ids
        raise ``AllocatorAuditError`` instead of silently corrupting the
        pool — every release path goes through the scheduler's audited
        ``_release_blocks``, so a violation here is a real bug."""
        for b in blocks:
            if not 0 <= b < self.num_blocks:
                raise AllocatorAuditError(f"free of foreign block id {b} "
                                          f"(pool has {self.num_blocks})")
            if b in self._free_set:
                raise AllocatorAuditError(f"double free of block {b}")
            self._free.append(b)
            self._free_set.add(b)

    def free_list(self) -> List[int]:
        """Snapshot of the free block ids (audit surface)."""
        return list(self._free)


def _table_leaf(leaf, table: Array):
    """Fit a host-owned (B, W) block table onto a cache table leaf,
    broadcasting over the leading layer-group axis of scanned caches."""
    if leaf.ndim == table.ndim + 1:                  # scanned: (G, B, W)
        return jnp.broadcast_to(table, (leaf.shape[0],) + table.shape)
    return table


def _with_tables(cache, table: Array):
    """Return ``cache`` with every block_table leaf set to ``table`` (B, W)."""
    def set_leaf(path, leaf):
        if path and path[-1] == _TABLE_KEY:
            return _table_leaf(leaf, table)
        return leaf
    return jax.tree_util.tree_map_with_path(set_leaf, cache)


def _bucket(n: int) -> int:
    """Round up to a power of two (bounds jit specializations)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _calibrate_engine(params, cfg: ModelConfig, qconfig: QConfig,
                      max_len: int, num_batches: int):
    """PTQ-calibrate activation ranges for the W8A8 serving tick.

    Runs ONCE at engine construction: a few synthetic uniform-token batches
    stream through the UN-jitted forward in 'collect' mode
    (``quant.ptq.calibrate``), the estimators close into static per-site
    (s, z), and the context flips to 'int8' — from then on the calibrated
    ranges are python-float closure constants of the jitted tick. Synthetic
    calibration is exactly the deployment-friendly protocol the paper
    argues the outlier-free models tolerate: per-tensor static ranges with
    no data-dependent tuning."""
    t = max(1, min(32, max_len, cfg.max_seq_len))
    key = jax.random.PRNGKey(0)
    batches = [
        {"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                      (2, t), 0, cfg.vocab_size)}
        for i in range(num_batches)
    ]

    def apply_fn(p, batch, ctx):
        return model_apply(p, cfg, batch, ctx=ctx)[0]

    ctx = calibrate(apply_fn, params, batches, qconfig,
                    num_batches=num_batches)
    ctx.use_int8_runtime()
    return ctx


class ContinuousBatcher:
    """Token-budget slot-pool scheduler over a shared static KV cache
    (dense or paged).

    Device state per slot row: KV cache (dense row or block-table view into
    the pool), next position and last sampled token; one jitted mixed step
    advances every runnable row per tick — decode rows by one token,
    prefilling rows by a prompt chunk — regardless of their (generally
    different) positions and phase."""

    def __init__(self, params, cfg: ModelConfig, batch_size: int,
                 max_len: int, eos_id: Optional[int] = None,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 gen: Optional[GenerateConfig] = None,
                 token_budget: int = 256,
                 prefill_chunk: Optional[int] = None,
                 admit_watermark: int = 0,
                 qconfig: Optional[QConfig] = None,
                 kv_int8: Optional[bool] = None,
                 calib_batches: int = 4,
                 prefill_budget: Optional[int] = None,
                 swap_break_even_tokens: Optional[int] = None,
                 swap_pool_bytes: Optional[int] = None,
                 swap_retry_limit: int = 3,
                 shed_infeasible: bool = True,
                 fault_shed_after: int = 8,
                 on_pool_exhausted: str = "raise",
                 debug_audit: bool = False) -> None:
        # ---- INT8 serving (W8A8 tick + quantized paged KV) -------------
        if kv_int8 is None:
            kv_int8 = qconfig is not None and paged
        if kv_int8 and not paged:
            raise ValueError(
                "kv_int8 requires paged=True: the int8 KV layout is the "
                "block pool + per-slot scale vectors (init_paged_cache)")
        self.kv_int8 = bool(kv_int8)
        self.qconfig = qconfig
        self._qctx = NO_QUANT
        if qconfig is not None:
            # W8A8 needs per-layer calibration sites and per-layer int8
            # weight slices, so the engine runs the unrolled layer path
            # (functionally identical — stacked scanned params are
            # tree_slice'd per group by model_apply's unrolled branch)
            if cfg.scan_layers:
                cfg = dataclasses.replace(cfg, scan_layers=False)
            self._qctx = _calibrate_engine(params, cfg, qconfig, max_len,
                                           calib_batches)
            params = attach_int8_weights(params, skip=qconfig.skip_patterns)
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.L = max_len
        # sampling config for the fused tick (greedy by default — parity
        # with GenerateConfig's temperature/top-k knobs; per-request seeds
        # come from Request.seed). eos_id arg wins over gen.eos_id.
        self._gen = gen if gen is not None else GenerateConfig()
        self.eos_id = eos_id if eos_id is not None else self._gen.eos_id
        self.paged = paged
        if token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        self.token_budget = token_budget
        self.admit_watermark = admit_watermark
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # requests that left the engine without completing: cancelled,
        # expired (deadline), timeout, or shed (infeasible / persistent
        # faults / pool exhaustion under on_pool_exhausted="shed")
        self.failed: List[Request] = []
        self._order = 0
        self._arrival = 0
        # ---- SLO / robustness knobs ------------------------------------
        # per-tick cap on PREFILL tokens (None = whole remaining budget):
        # bounds the mixed tick's size when arrivals burst, protecting
        # decode-tick p99 at a TTFT cost
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 (or None)")
        self.prefill_budget = prefill_budget
        # swap-vs-recompute cost rule threshold (None = swap disabled):
        # victims with >= this many cached tokens copy out, shorter ones
        # recompute (see _swap_eligible for the bytes-vs-recompute story)
        self.swap_break_even_tokens = swap_break_even_tokens
        self.swap_pool_bytes = swap_pool_bytes   # host swap capacity cap
        self.swap_retry_limit = swap_retry_limit
        self.shed_infeasible = shed_infeasible
        self.fault_shed_after = fault_shed_after
        if on_pool_exhausted not in ("raise", "shed"):
            raise ValueError("on_pool_exhausted must be 'raise' or 'shed'")
        self.on_pool_exhausted = on_pool_exhausted
        self.debug_audit = debug_audit
        # caller-owned clock (step(now=...)); defaults to a tick counter
        self.now = 0.0
        self._tick_ewma: Optional[float] = None   # est. virtual tick cost
        self._prev_advanced = False
        self._alloc_fault = False      # spurious alloc denial seen this tick
        self._fault_streak = 0         # consecutive faulted no-progress ticks
        self._swap_bytes = 0           # host bytes currently held by swaps
        # chaos hook: called before each swap-in; returning False denies it
        # (counts as a retry attempt -> bounded degradation to recompute)
        self._swap_in_gate: Optional[Callable[[Request], bool]] = None
        # total REAL tokens processed by the most recent step() across all
        # sub-steps — the workload harness's virtual-clock cost input
        self.last_tick_tokens = 0
        # counts vector of the most recent sub-step (observability + tests:
        # a mixed tick shows >= 2 entries > 1 next to entries == 1)
        self.last_counts: Optional[np.ndarray] = None
        if paged:
            self.block_size = block_size
            n_entries = -(-max_len // block_size)
            # default pool = dense-equivalent memory (B rows of max_len)
            self.num_blocks = num_blocks if num_blocks is not None \
                else batch_size * n_entries
            self.allocator = BlockAllocator(self.num_blocks)
            self.tables = np.full((batch_size, n_entries), -1, np.int32)
            # host tables are mirrored into the device cache lazily: only
            # ticks after an admit/alloc/retire/preempt pay the re-upload
            self._tables_dirty = True
            make_cache = lambda b: init_paged_cache(  # noqa: E731
                cfg, b, max_len, self.num_blocks, block_size,
                kv_int8=self.kv_int8)
        else:
            make_cache = lambda b: init_cache(cfg, b, max_len)  # noqa: E731
        self.cache = make_cache(batch_size)
        # fresh batch-1 state template: admission resets the slot's
        # batch-led rows (ring pos_ids, recurrent states, dense KV) from it
        # so the previous occupant cannot leak into the new request's
        # prefill. In paged mode only its batch-led leaves are ever read —
        # build it with a 1-block pool so the template does not duplicate
        # the real pool's device memory
        self._row_template = init_paged_cache(cfg, 1, max_len, 1, block_size,
                                              kv_int8=self.kv_int8) \
            if paged else make_cache(1)
        kinds = cfg.pattern + cfg.tail_pattern
        # recurrent states have no per-token write index to mask, so ragged
        # mixed steps are not expressible — such configs run split
        # decode/uniform-prefill sub-steps instead (see module docstring)
        self._uniform = any(k in _RECURRENT_KINDS for k in kinds)
        # a prefill chunk on a local_attn layer must fit the ring, and its
        # own writes must not collide inside it
        ring_cap = min(max_len, cfg.window) \
            if (any(k == "local_attn" for k in kinds) and cfg.window) \
            else token_budget
        self._chunk_cap = min(prefill_chunk or token_budget, token_budget,
                              ring_cap)
        # which leaves are batch-free (the paged global pools, shared by all
        # rows) vs batch-led (dense/ring KV, recurrent states, block
        # tables): exactly the leaves whose shape ignores the batch argument
        spec1, spec2 = (jax.eval_shape(lambda b=b: make_cache(b))
                        for b in (1, 2))
        self._batch_free = jax.tree_util.tree_map(
            lambda a, b: a.shape == b.shape, spec1, spec2)

        # the jitted fused tick lives with the other serving programs in
        # decode.py; calibrated int8 ranges ride along as closure constants
        self._step_fn = make_mixed_step(cfg, self._gen, self._qctx)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request, rejecting impossible ones up front — a lazy
        admit-time failure would wedge the queue head and strand every
        queued request behind it. (Preemption re-queues bypass this:
        resume lengths are bounded by construction.)"""
        t = len(req.prompt)
        if t == 0:
            raise ValueError(
                f"request uid={req.uid}: empty prompt (there is no logits "
                f"position to sample a first token from)")
        if t > self.L - 1:
            raise ValueError(
                f"request uid={req.uid}: {t} prompt tokens do not fit a "
                f"max_len={self.L} {'row' if self.paged else 'slot'} "
                f"(>= 1 position must remain for decode)")
        if self.paged and self._blocks_for(t + 1) > self.num_blocks:
            raise ValueError(
                f"request uid={req.uid} needs {self._blocks_for(t + 1)} "
                f"blocks; the pool only has {self.num_blocks}")
        if req.arrival is None:
            req.arrival = self._arrival
            self._arrival += 1
        if req.submit_time is None:
            req.submit_time = self.now
        req.status = "queued"
        self.queue.append(req)

    def cancel(self, uid: int, status: str = "cancelled") -> bool:
        """Cancel a request by uid — queued, mid-prefill, or decoding —
        the same tick: its blocks are released immediately, queued prefill
        chunks are dropped with the cursor, and any generated tokens are
        delivered as a partial ``output``. Returns False if the uid is not
        live (already finished or unknown)."""
        for j, req in enumerate(self.queue):
            if req.uid == uid:
                self.queue.pop(j)
                self._fail(req, status)
                return True
        for i, s in enumerate(self.slots):
            if s.req is not None and s.req.uid == uid:
                self._evict(i, status)
                return True
        return False

    def _fail(self, req: Request, status: str,
              output: Optional[List[int]] = None) -> None:
        """Terminal non-success: stamp status/finish time, release any swap
        bytes, deliver a (possibly partial) output, move to ``failed``."""
        if req.swapped is not None:
            self._swap_bytes -= req.swapped.nbytes
            if output is None and req.swapped.generated:
                output = req.swapped.generated
            req.swapped = None
        if output is None and req.resume_generated:
            output = req.resume_generated
        req.output = np.asarray(output if output is not None else [],
                                np.int32)
        req.status = status
        req.finish_time = self.now
        self.failed.append(req)

    def _evict(self, i: int, status: str) -> None:
        """Terminally remove slot ``i``'s occupant (cancel/expire/shed):
        blocks released through the audited path, partial tokens kept."""
        s = self.slots[i]
        out = (s.prefill.resume if s.prefill is not None and s.prefill.resume
               else s.generated)
        self._release_blocks(i)
        self._fail(s.req, status, output=list(out))
        self.slots[i] = _Slot()

    def _release_blocks(self, i: int) -> None:
        """The ONE path blocks travel back to the free list (retire,
        preempt, cancel, shed all route here): frees the slot's blocks,
        clears its table row, marks the device mirror dirty. Keeping a
        single audited release point is what makes the allocator audit's
        no-leak/no-double-free invariant cheap to uphold."""
        s = self.slots[i]
        if not self.paged:
            return
        if s.blocks:
            self.allocator.free(s.blocks)
            s.blocks = []
        self.tables[i] = -1
        self._tables_dirty = True

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def _blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def _reset_row(self, i: int) -> None:
        """Reset slot ``i``'s batch-led device state (dense/ring KV rows,
        ring pos_ids, recurrent h/conv/cell) to the fresh template before a
        new occupant starts prefilling: stale ring position ids or
        recurrent state from the previous occupant would otherwise leak
        into the new request. Paged pool leaves are shared by all rows and
        left alone (newly allocated blocks are fully overwritten before any
        causally reachable read), and block tables stay host-owned."""
        def pick(path, batch_free, live_leaf, tmpl_leaf):
            if (path and path[-1] == _TABLE_KEY) or batch_free:
                return live_leaf
            # scanned caches stack layer groups in front: (G, B, ...)
            ax = 1 if path and path[0] == jax.tree_util.DictKey("groups") \
                else 0
            dst = (slice(None),) * ax + (i,)
            src = (slice(None),) * ax + (0,)
            return live_leaf.at[dst].set(tmpl_leaf[src])

        self.cache = jax.tree_util.tree_map_with_path(
            pick, self._batch_free, self.cache, self._row_template)

    def _admit_key(self, j: int):
        """Admission order: priority desc, then earliest deadline first
        among equals (deadline-free requests sort last within their
        priority), then arrival — so SLO-bearing traffic is both
        prioritized by tier and EDF-scheduled inside a tier."""
        r = self.queue[j]
        d = r.deadline if r.deadline is not None else float("inf")
        return (-r.priority, d, r.arrival)

    def _admit(self) -> None:
        """Bind queued requests to free slots in ``_admit_key`` order.
        Admission does NOT prefill — it resets the slot row and hands the
        prompt to the chunked tick — so its only gates are a free slot
        and, in paged mode, the free-block watermark (admission stops
        while ``free_blocks < admit_watermark``, keeping headroom for the
        rows already decoding instead of thrashing the pool). A swapped
        request instead restores its copied-out state into freshly
        allocated blocks (all-or-nothing); while its swap-in is denied it
        is deferred for the tick rather than blocking the queue head."""
        deferred: set = set()
        for i in self._free_slots():
            while True:
                cands = [j for j, r in enumerate(self.queue)
                         if r.uid not in deferred]
                if not cands:
                    return
                if self.paged and \
                        self.allocator.available < self.admit_watermark:
                    return
                j = min(cands, key=self._admit_key)
                req = self.queue[j]
                if req.swapped is not None:
                    ok = self._try_swap_in(i, j)
                    if ok is None:       # degraded to recompute: re-pick
                        continue
                    if not ok:           # denied this tick: try next cand
                        deferred.add(req.uid)
                        continue
                    break                # restored into slot i
                self.queue.pop(j)
                self._bind_slot(i, req)
                break

    def _bind_slot(self, i: int, req: Request) -> None:
        """Fresh (or recompute-resume) admission into slot ``i``."""
        resume = req.resume_generated
        req.resume_generated = None
        if resume:
            feed = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(resume[:-1], np.int32)])
        else:
            feed = np.asarray(req.prompt, np.int32)
        self._reset_row(i)
        key = np.asarray(jax.random.PRNGKey(
            req.seed if req.seed is not None else req.uid))
        self.slots[i] = _Slot(
            req=req, pos=0, generated=[], blocks=[], order=self._order,
            key=key,
            prefill=PrefillState(feed=feed,
                                 resume=list(resume) if resume else None))
        self._order += 1
        req.status = "running"

    # ---- swapped preemption ------------------------------------------
    def _swap_eligible(self, s: _Slot) -> bool:
        """The bytes-vs-recompute cost rule, reduced to a token threshold:
        swap-out cost is the row's live KV *bytes* — linear in cached
        tokens, a pure copy — while recompute-resume re-runs the model
        over every cached token (attention makes it superlinear, and even
        the linear term is a full forward per token, orders of magnitude
        more work per token than a memcpy). Both costs scale with the same
        token count, so 'swap when bytes beat recompute' is 'swap when
        the cached context is longer than a break-even token count'."""
        if self.swap_break_even_tokens is None or not self.paged:
            return False
        if s.pos < self.swap_break_even_tokens:
            return False
        if self.swap_pool_bytes is not None and \
                self._swap_bytes >= self.swap_pool_bytes:
            return False        # host swap pool full: fall back to recompute
        return True

    def _swap_out(self, i: int) -> SwappedState:
        """Copy slot ``i``'s live device state to host: its pool blocks
        (K/V and, for int8 KV, the per-slot scale vectors travel together
        — a block's scales are meaningless without it) in table order from
        every batch-free pool leaf, plus its row slice of every batch-led
        leaf (ring KV/pos_ids, recurrent states). The blocks themselves
        are released by the caller — a swapped request holds none."""
        s = self.slots[i]
        idx = jnp.asarray(s.blocks, jnp.int32)
        pool: Dict[Tuple, np.ndarray] = {}
        row: Dict[Tuple, np.ndarray] = {}

        def grab(path, batch_free, leaf):
            if path and path[-1] == _TABLE_KEY:
                return
            ax = 1 if path and path[0] == _GROUPS_KEY else 0
            if batch_free:
                pool[path] = np.asarray(jnp.take(leaf, idx, axis=ax))
            else:
                row[path] = np.asarray(leaf[(slice(None),) * ax + (i,)])

        jax.tree_util.tree_map_with_path(grab, self._batch_free, self.cache)
        st = s.prefill
        nbytes = sum(a.nbytes for a in pool.values()) \
            + sum(a.nbytes for a in row.values())
        return SwappedState(
            pool=pool, row=row, n_blocks=len(s.blocks), pos=s.pos,
            generated=list(s.generated),
            prefill=None if st is None else PrefillState(
                feed=st.feed, done=st.done,
                resume=list(st.resume) if st.resume else None),
            key=None if s.key is None else np.array(s.key),
            nbytes=nbytes)

    def _try_swap_in(self, i: int, j: int) -> Optional[bool]:
        """Attempt to restore queued request ``j`` into slot ``i``.
        Returns True on success, False when denied this tick (pool cannot
        hand out the blocks, or the chaos gate says no — bounded retry),
        and None when the retry budget is exhausted and the request
        degraded to recompute-resume (graceful degradation: recompute can
        always make incremental progress)."""
        req = self.queue[j]
        sw = req.swapped
        denied = self._swap_in_gate is not None and \
            not self._swap_in_gate(req)
        blocks = None if denied else self.allocator.alloc(sw.n_blocks)
        if blocks is None:
            sw.attempts += 1
            if sw.attempts > self.swap_retry_limit:
                self._drop_swap(req)
                return None
            return False
        self.queue.pop(j)
        idx = jnp.asarray(blocks, jnp.int32)

        def put(path, batch_free, leaf):
            if path and path[-1] == _TABLE_KEY:
                return leaf
            ax = 1 if path and path[0] == _GROUPS_KEY else 0
            if batch_free:
                sel = (slice(None),) * ax + (idx,)
                return leaf.at[sel].set(jnp.asarray(sw.pool[path],
                                                    leaf.dtype))
            sel = (slice(None),) * ax + (i,)
            return leaf.at[sel].set(jnp.asarray(sw.row[path], leaf.dtype))

        self.cache = jax.tree_util.tree_map_with_path(
            put, self._batch_free, self.cache)
        self.tables[i, :len(blocks)] = blocks
        self.tables[i, len(blocks):] = -1
        self._tables_dirty = True
        self.slots[i] = _Slot(req=req, pos=sw.pos,
                              generated=list(sw.generated),
                              blocks=list(blocks), order=self._order,
                              key=sw.key, prefill=sw.prefill)
        self._order += 1
        self._swap_bytes -= sw.nbytes
        req.swapped = None
        req.status = "running"
        return True

    def _drop_swap(self, req: Request) -> None:
        """Degrade a swapped request to recompute-resume (swap-in kept
        failing): reconstruct the recompute state from the host copy and
        release the swap bytes. Outputs stay exact — recompute-resume and
        swap-resume are bitwise equivalent by construction."""
        sw = req.swapped
        req.swapped = None
        self._swap_bytes -= sw.nbytes
        if sw.prefill is not None and sw.prefill.resume:
            req.resume_generated = list(sw.prefill.resume)
        elif sw.generated:
            req.resume_generated = list(sw.generated)

    def _preempt(self, i: int) -> None:
        """Evict slot ``i`` on pool pressure and re-queue it (the original
        arrival rank keeps it ahead of later equal-priority arrivals).
        Victims past the swap break-even copy their live state out to host
        (``SwappedState``: resume is a copy-in, no recompute); short
        victims stash their generated tokens for recompute-resume. Either
        way the blocks go back through the audited release path."""
        s = self.slots[i]
        req = s.req
        if self._swap_eligible(s):
            req.swapped = self._swap_out(i)
            self._swap_bytes += req.swapped.nbytes
            req.resume_generated = None
        elif s.prefill is not None and s.prefill.resume:
            req.resume_generated = list(s.prefill.resume)
        else:
            req.resume_generated = list(s.generated)
        self._release_blocks(i)
        req.status = "queued"
        self.queue.append(req)
        self.slots[i] = _Slot()

    def preempt_slot(self, i: int) -> None:
        """Force-preempt live slot ``i`` (chaos storms, tests): exactly the
        pool-pressure eviction path, including the swap-vs-recompute
        choice."""
        if self.slots[i].req is None:
            raise ValueError(f"slot {i} is not occupied")
        self._preempt(i)

    # ------------------------------------------------------------------
    def _grow_blocks(self, i: int, n_tokens: int) -> int:
        """Paged: try to grow slot ``i``'s block list to cover its next
        ``n_tokens`` writes; allocates as many of the missing blocks as the
        pool can give. Returns how many of the ``n_tokens`` writes are now
        covered (possibly 0)."""
        s = self.slots[i]
        need = self._blocks_for(s.pos + n_tokens) - len(s.blocks)
        if need > 0:
            take = min(need, self.allocator.available)
            got = self.allocator.alloc(take) if take > 0 else None
            if take > 0 and got is None:
                # the allocator denied a request its own 'available' said
                # it could serve: a transient fault (chaos injection), not
                # pool pressure — flag it so _plan stalls instead of
                # preempting (freeing blocks cannot cure a denial)
                self._alloc_fault = True
            if got:
                self.tables[i, len(s.blocks):len(s.blocks) + len(got)] = got
                s.blocks.extend(got)
                self._tables_dirty = True
        return max(0, min(n_tokens, len(s.blocks) * self.block_size - s.pos))

    def _plan(self, want_decode: bool, want_prefill: bool,
              allow_preempt: bool) -> np.ndarray:
        """Carve this sub-step's per-row token counts against the budget,
        allocating paged blocks as needed. Decode rows come first (1 token
        each — inter-token latency is the knob the budget must never
        starve), then prefill chunks: earliest deadline first among
        deadline-bearing rows, admission order after them, against the
        smaller of the remaining budget and ``prefill_budget`` (the p99
        guard: a burst of admissions cannot inflate the tick past the
        prefill cap). If the pool is exhausted and NO row can advance,
        preempt the most recently admitted stalled row and retry — unless
        the failure was a transient allocator fault, which stalls the tick
        instead (preemption cannot cure a denial). A single stalled row
        holding the whole pool means the pool is simply too small for the
        request: raise, or shed it under ``on_pool_exhausted='shed'``."""
        while True:
            counts = np.zeros(self.B, np.int32)
            stalled: List[int] = []
            budget = self.token_budget
            pleft = self.prefill_budget if self.prefill_budget is not None \
                else self.token_budget
            if want_decode:
                for i, s in enumerate(self.slots):
                    if s.req is None or s.prefill is not None:
                        continue
                    if self.paged and self._grow_blocks(i, 1) < 1:
                        stalled.append(i)
                        continue
                    counts[i] = 1
                    budget -= 1
            if want_prefill:
                def edf(i):
                    s = self.slots[i]
                    d = s.req.deadline if s.req.deadline is not None \
                        else float("inf")
                    return (d, s.order)
                pre = sorted(
                    (i for i, s in enumerate(self.slots)
                     if s.req is not None and s.prefill is not None),
                    key=edf)
                uniform_c = None
                if self._uniform and pre:
                    uniform_c = min(min(self.slots[i].prefill.remaining
                                        for i in pre),
                                    self._chunk_cap, max(budget, 0),
                                    max(pleft, 0))
                for i in pre:
                    if budget <= 0 or pleft <= 0:
                        break
                    s = self.slots[i]
                    if uniform_c is not None:
                        if uniform_c > min(budget, pleft):
                            break
                        c = uniform_c
                    else:
                        c = min(s.prefill.remaining, self._chunk_cap,
                                budget, pleft)
                    if c > 0 and self.paged:
                        c = self._grow_blocks(i, c)
                        if self._uniform and 0 < c < uniform_c:
                            # a short chunk would make the step ragged;
                            # recurrent rows sit this tick out instead
                            c = 0
                    if c <= 0:
                        stalled.append(i)
                        continue
                    counts[i] = c
                    budget -= c
                    pleft -= c
            if counts.any() or not stalled:
                return counts
            if not allow_preempt:
                return counts
            if self._alloc_fault:
                # transient fault: stall the tick and retry next step();
                # step() bounds the streak with priority-ordered shedding
                return counts
            occupied = sum(s.req is not None for s in self.slots)
            if occupied == 1:
                s = self.slots[stalled[0]]
                if self.on_pool_exhausted == "shed":
                    self._evict(stalled[0], "shed")
                    continue
                raise RuntimeError(
                    f"block pool too small: request uid={s.req.uid} holds "
                    f"{len(s.blocks)}/{self.num_blocks} blocks and still "
                    f"needs more; increase num_blocks")
            self._preempt(max(stalled, key=lambda i: self.slots[i].order))

    def _live_width(self) -> Optional[int]:
        """Static block-table read width for this tick: the max blocks any
        occupied slot holds, rounded up to a power of two (so at most
        log2(W)+1 distinct jit specializations exist). Allocation is
        prefix-dense — tables fill from entry 0 — so every live token of
        every row sits inside the first ``live_width`` entries and slicing
        the READ path there is exact. Returns None in dense mode."""
        if not self.paged:
            return None
        held = max((len(s.blocks) for s in self.slots if s.req is not None),
                   default=1)
        return min(_bucket(held), self.tables.shape[1])

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s.req is None or s.prefill is not None:
                continue
            out_len = len(s.generated)
            hit_eos = self.eos_id is not None and s.generated and \
                s.generated[-1] == self.eos_id
            if out_len >= s.req.max_new_tokens or hit_eos or s.pos >= self.L - 1:
                s.req.output = np.asarray(s.generated, np.int32)
                s.req.status = "done"
                s.req.finish_time = self.now
                self.done.append(s.req)
                self._release_blocks(i)
                self.slots[i] = _Slot()

    def _substep(self, want_decode: bool = True, want_prefill: bool = True,
                 allow_preempt: bool = True) -> int:
        """Plan, assemble and run ONE fused forward; apply its results to
        the slots. Returns the number of rows that advanced."""
        counts = self._plan(want_decode, want_prefill, allow_preempt)
        run = np.flatnonzero(counts)
        if run.size == 0:
            return 0
        self.last_counts = counts.copy()
        # recurrent rows would feed any padding tail into their recurrence
        # (no per-token write index to mask), so uniform mode uses the
        # exact chunk length — one compile per distinct prompt-chunk size,
        # the same specialization behavior as a one-shot prefill engine
        # repro: ignore[R002] uniform recurrent rows need the exact chunk length
        t_step = int(counts.max()) if self._uniform \
            else _bucket(int(counts.max()))
        tokens = np.zeros((self.B, t_step), np.int32)
        pos = np.zeros((self.B,), np.int32)
        final = {}
        for i in run:
            s = self.slots[i]
            c = int(counts[i])
            pos[i] = s.pos
            if s.prefill is None:
                tokens[i, 0] = s.generated[-1] if s.generated else 0
            else:
                st = s.prefill
                tokens[i, :c] = st.feed[st.done:st.done + c]
                final[i] = st.done + c == len(st.feed)
        keys = np.stack([s.key if s.key is not None
                         else np.zeros((2,), np.uint32) for s in self.slots])
        if self.paged and self._tables_dirty:
            self.cache = _with_tables(self.cache, jnp.asarray(self.tables))
            self._tables_dirty = False
        live_widths = jnp.asarray([len(s.blocks) for s in self.slots],
                                  jnp.int32) if self.paged else None
        # the step returns its block tables unchanged, so in steady state
        # (no admissions/retirements) the paged tick is as cheap as the
        # dense one: no table upload, no tree surgery
        nxt, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos),
            jnp.asarray(counts), jnp.asarray(keys),
            self._live_width(), live_widths)
        nt = np.asarray(nxt)
        self.last_tick_tokens += int(counts.sum())
        for i in run:
            s = self.slots[i]
            c = int(counts[i])
            if s.prefill is None:
                s.generated.append(int(nt[i]))
                s.pos += 1
            else:
                st = s.prefill
                st.done += c
                s.pos += c
                if final[i]:
                    # chunk-aware sampling: only the final chunk's last-token
                    # logits produce a token — the request's first generated
                    # token at position len(feed), drawn under the same
                    # position-keyed rule as every decode tick. A resumed
                    # request restores its stashed continuation instead.
                    s.generated = list(st.resume) if st.resume \
                        else [int(nt[i])]
                    s.prefill = None
            if s.generated and s.req.first_token_time is None:
                s.req.first_token_time = self.now
        return int(run.size)

    # ---- SLO enforcement / degradation -------------------------------
    def _min_ticks_left(self, req: Request) -> int:
        """Optimistic lower bound on ticks to finish a QUEUED request:
        prefill chunks at the full chunk cap plus one decode tick per
        remaining token. Used only to shed provably-late requests, so it
        must underestimate, never overestimate."""
        if req.swapped is not None:
            sw = req.swapped
            feed_left = sw.prefill.remaining if sw.prefill is not None else 0
            dec = max(0, req.max_new_tokens - len(sw.generated))
        else:
            resume = req.resume_generated or []
            feed_left = len(req.prompt) + max(0, len(resume) - 1)
            dec = max(0, req.max_new_tokens - len(resume))
        cap = min(self._chunk_cap,
                  self.prefill_budget or self.token_budget)
        return -(-feed_left // max(cap, 1)) + dec

    def _enforce_slos(self) -> None:
        """Same-tick cancellation of requests past their deadline or
        timeout — queued, mid-prefill or decoding — plus early shedding of
        queued requests whose optimistic remaining work already overruns
        their deadline (only once a tick-cost estimate exists)."""
        now = self.now
        for req in list(self.queue):
            late = req.deadline is not None and now > req.deadline
            timed = req.timeout is not None and req.submit_time is not None \
                and now - req.submit_time > req.timeout
            if late or timed:
                self.queue.remove(req)
                self._fail(req, "expired" if late else "timeout")
            elif (self.shed_infeasible and req.deadline is not None
                  and self._tick_ewma is not None
                  and now + self._min_ticks_left(req) * self._tick_ewma
                  > req.deadline):
                self.queue.remove(req)
                self._fail(req, "shed")
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            req = s.req
            late = req.deadline is not None and now > req.deadline
            timed = req.timeout is not None and req.submit_time is not None \
                and now - req.submit_time > req.timeout
            if late or timed:
                self._evict(i, "expired" if late else "timeout")

    def _shed_one(self) -> None:
        """Persistent-fault degradation: drop exactly ONE victim, in
        strict priority order — lowest priority first, newest arrival
        among equals — preferring queued requests over running rows (a
        running row may still drain what it holds)."""
        if self.queue:
            j = min(range(len(self.queue)),
                    key=lambda j: (self.queue[j].priority,
                                   -(self.queue[j].arrival or 0)))
            req = self.queue.pop(j)
            self._fail(req, "shed")
            return
        live = [i for i, s in enumerate(self.slots) if s.req is not None]
        if live:
            i = min(live, key=lambda i: (self.slots[i].req.priority,
                                         -self.slots[i].order))
            self._evict(i, "shed")

    def audit(self) -> None:
        """Block-accounting invariant: every physical block is exactly one
        of free or owned-by-a-live-row; host block tables mirror slot
        state; swapped requests hold zero device blocks; swap-byte
        accounting balances. Raises ``AllocatorAuditError`` on any
        violation — the chaos harness calls this after every step, and
        ``debug_audit=True`` makes the engine self-check every tick."""
        if not self.paged:
            return
        owner: Dict[int, int] = {}
        for i, s in enumerate(self.slots):
            if s.req is None:
                if s.blocks:
                    raise AllocatorAuditError(
                        f"empty slot {i} holds blocks {s.blocks}")
                if not (self.tables[i] == -1).all():
                    raise AllocatorAuditError(
                        f"empty slot {i} has stale table entries")
                continue
            for b in s.blocks:
                if b in owner:
                    raise AllocatorAuditError(
                        f"block {b} owned by slots {owner[b]} and {i}")
                owner[b] = i
            w = len(s.blocks)
            if list(self.tables[i, :w]) != s.blocks or \
                    not (self.tables[i, w:] == -1).all():
                raise AllocatorAuditError(
                    f"slot {i} table row {self.tables[i].tolist()} does "
                    f"not mirror its blocks {s.blocks}")
        free = self.allocator.free_list()
        seen = sorted(free + list(owner))
        if seen != list(range(self.num_blocks)):
            missing = set(range(self.num_blocks)) - set(seen)
            dups = [b for b in set(seen) if seen.count(b) > 1]
            raise AllocatorAuditError(
                f"block accounting broken: leaked={sorted(missing)} "
                f"duplicated={dups} (free={len(free)} owned={len(owner)} "
                f"of {self.num_blocks})")
        swap_bytes = sum(r.swapped.nbytes for r in self.queue
                         if r.swapped is not None)
        if swap_bytes != self._swap_bytes:
            raise AllocatorAuditError(
                f"swap byte accounting broken: held={self._swap_bytes} "
                f"but queued swaps sum to {swap_bytes}")

    def step(self, now: Optional[float] = None) -> int:
        """One scheduler tick: enforce SLOs, retire, admit, run the mixed
        token-budget step (or the split decode/uniform-prefill sub-steps
        for recurrent configs), retire again. ``now`` is the caller's
        clock (virtual or wall — deadlines/timeouts are compared against
        it); omitted, it advances an internal tick counter by 1. Returns
        the number of rows advanced (0 = stalled or idle, never an
        exception under transient faults)."""
        now = self.now + 1.0 if now is None else float(now)
        dt = now - self.now
        if dt > 0 and self._prev_advanced:
            # per-tick cost estimate for infeasibility shedding; only
            # ticks that did work count (idle clock jumps would bloat it)
            self._tick_ewma = dt if self._tick_ewma is None \
                else 0.8 * self._tick_ewma + 0.2 * dt
        self.now = now
        self._alloc_fault = False
        self.last_tick_tokens = 0
        self._retire()
        self._enforce_slos()
        self._admit()
        if self._uniform:
            has_pre = any(s.req is not None and s.prefill is not None
                          for s in self.slots)
            n = self._substep(want_prefill=False,
                              allow_preempt=not has_pre)
            if has_pre:
                n += self._substep(want_decode=False,
                                   allow_preempt=(n == 0))
        else:
            n = self._substep()
        self._retire()
        self._prev_advanced = n > 0
        if self._alloc_fault and n == 0:
            self._fault_streak += 1
            if self._fault_streak > self.fault_shed_after:
                # the fault is persistent: degrade by policy instead of
                # queueing unboundedly — one victim per tick, lowest
                # priority first
                self._shed_one()
        elif not self._alloc_fault:
            self._fault_streak = 0
        if self.debug_audit:
            self.audit()
        return n

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(s.req for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
