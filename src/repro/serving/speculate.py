"""Model-free speculative drafting for the continuous batcher.

Speculative decoding splits token generation into a cheap *drafter* that
guesses the next ``k`` tokens and the real model *verifying* all ``k``
guesses in one forward pass. The drafter here is the model-free n-gram /
prompt-lookup scheme (Saxena's prompt-lookup decoding, the assisted-
generation variant HF ships): the last ``n`` tokens of a row's own
prompt+output history are searched for an earlier occurrence, and the
tokens that followed that occurrence become the draft. No draft model,
no extra memory, no training — it exploits the empirical fact that
generation (summaries, code, chat with quoting, anything repetitive)
re-uses long spans of its own context.

Why verification is *lossless* here (not merely "close"): the engine's
sampling rule is position-keyed — the token at logical position ``p`` is
drawn with ``fold_in(request_key, p)`` from that position's logits
(greedy is the temperature-0 special case). Sampling is therefore a pure
function of (request seed, position, logits), and the verifying forward
computes exactly the logits plain decoding would have seen at every
draft position (same weights, same quantized cache, same attention
read). A draft token is accepted iff it EQUALS the verifier's sample at
its position, so the emitted stream is bitwise identical to the
non-speculative engine — fp and int8, greedy and sampled. The draft
quality only moves throughput, never content.

The scheduler-side integration (multi-block allocation for ``k+1``
writes per tick, rejected-write hygiene, accounting) lives in
``repro.serving.scheduler``; the verifying tick is
``repro.serving.decode.make_spec_step``. See docs/serving.md
"Speculative decoding".
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Knobs for ``ContinuousBatcher(..., spec=SpecConfig(...))``.

    ``k``: max draft tokens proposed per decode row per tick — a row
    advances by 1..k+1 tokens per tick (the +1 is the verifier's own
    "bonus" sample at the first rejected/exhausted position, so a tick
    with speculation NEVER yields fewer tokens than one without).
    ``max_ngram``/``min_ngram``: suffix lengths tried by the drafter,
    longest first — longer matches are rarer but much more predictive.
    ``min_context``: don't bother drafting before this many tokens of
    history exist (a 2-token context has nothing to look up)."""
    k: int = 4
    max_ngram: int = 3
    min_ngram: int = 1
    min_context: int = 4

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")
        if not 1 <= self.min_ngram <= self.max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"min_ngram={self.min_ngram} max_ngram={self.max_ngram}")
        if self.min_context < 1:
            raise ValueError("SpecConfig.min_context must be >= 1")


class NGramDrafter:
    """Prompt-lookup drafter: propose the continuation of the most
    recent earlier occurrence of the context's own suffix.

    Host-side and stateless across calls — the "draft model" is the
    row's context itself, so there is nothing to train, snapshot, swap
    or invalidate on preemption. O(max_ngram * len(context)) numpy per
    call, negligible next to the tick's forward."""

    def __init__(self, spec: SpecConfig) -> None:
        self.spec = spec

    def propose(self, prompt: np.ndarray, generated: Sequence[int],
                k: int) -> List[int]:
        """Up to ``k`` draft tokens for a row whose history is
        ``prompt + generated``. Empty list = no match (the tick then
        degrades to a plain 1-token decode for this row)."""
        spec = self.spec
        ctx = np.concatenate([np.asarray(prompt, np.int64),
                              np.asarray(generated, np.int64)])
        n_ctx = len(ctx)
        if k <= 0 or n_ctx < spec.min_context:
            return []
        for n in range(min(spec.max_ngram, n_ctx - 1),
                       spec.min_ngram - 1, -1):
            pat = ctx[n_ctx - n:]
            # candidate starts: first-token matches strictly before the
            # suffix itself (a window may overlap INTO the suffix — the
            # continuation it predicts is still real history)
            starts = np.flatnonzero(ctx[:n_ctx - n] == pat[0])
            for i in starts[::-1]:                 # most recent first
                if np.array_equal(ctx[i:i + n], pat):
                    cont = ctx[i + n:i + n + k]
                    return [int(t) for t in cont]
        return []
