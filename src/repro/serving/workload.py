"""Open-loop workload harness: seeded traces + a virtual-clock runner.

The throughput benchmark is *closed-loop*: it submits N requests and runs
the engine to empty, so the engine is never actually under pressure —
arrivals wait politely for capacity, and "tokens/sec at batch B" says
nothing about what happens when traffic does not cooperate. Real traffic
is *open-loop*: requests arrive on their own schedule (bursty Poisson
inter-arrivals), with heavy-tailed prompt/output lengths, in priority
tiers with per-request deadlines, and some of them are cancelled midway.
Under open-loop load the headline metric stops being throughput and
becomes **goodput**: tokens delivered by requests that finished *inside
their SLO* — a request completed after its deadline is wasted work, and
an engine that never sheds serves everyone late.

Three pieces:

  * ``generate_trace(WorkloadConfig)`` — a deterministic-per-seed list of
    ``TraceEntry`` (arrival time, tier, priority, prompt, output length,
    deadline, optional cancellation time). Prompt/output lengths are
    clipped lognormals (heavy tails: a few long stragglers dominate pool
    pressure); deadlines derive from the tier's TTFT + per-token SLOs.
  * ``run_workload(batcher, trace, ...)`` — drives ``ContinuousBatcher``
    through the trace on a **virtual clock**: each tick costs
    ``TickCostModel.cost(tokens processed)`` virtual seconds (wall-clock
    mode is available for real benchmarking, but the virtual clock makes
    every run bit-deterministic per seed — CI can assert on it). Arrivals
    are submitted when the clock passes them, cancellations issued when
    due, and the engine's own SLO machinery (deadline expiry, infeasible
    shedding, priority admission) does the rest.
  * ``WorkloadReport`` — goodput (global and per tier), delivered tokens,
    TTFT p50/p99 per tier, p99 decode-tick stall (the cost of ticks in
    which at least one row was decoding — the inter-token latency a user
    actually observes), per-tier TPOT (time per banked output token, from
    each done request's own first-token->finish span), and per-status
    failure counts.

Accounting under speculative decoding: a tick is no longer one token per
decode row. The runner charges the clock with the engine's
``last_tick_tokens`` — FED tokens, drafts included, because that is the
compute the forward actually paid — but throughput/TPOT numerators use
*banked* tokens (``last_tick_new_tokens`` per tick; request outputs at
report time), so a rejected draft makes the engine look slower, never
faster. Goodput already counts ``req.output`` lengths, which are banked
by construction.

The runner never reaches into the engine's scheduling decisions — it only
submits, cancels, and advances the clock — so the same trace can drive
dense/paged/int8 engines and the reports are directly comparable.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.scheduler import ContinuousBatcher, Request


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One priority tier of the traffic mix. ``ttft_slo``/``tpot_slo``
    define each request's deadline: arrival + ttft_slo + output_len *
    tpot_slo (time to first token, then a per-token drip rate)."""
    name: str
    weight: float          # share of requests drawn from this tier
    priority: int          # ContinuousBatcher admission priority
    ttft_slo: float        # virtual seconds allowed to the first token
    tpot_slo: float        # virtual seconds allowed per output token


DEFAULT_TIERS: Tuple[TierSpec, ...] = (
    TierSpec("interactive", weight=0.5, priority=2, ttft_slo=0.5,
             tpot_slo=0.05),
    TierSpec("standard", weight=0.35, priority=1, ttft_slo=2.0,
             tpot_slo=0.2),
    TierSpec("batch", weight=0.15, priority=0, ttft_slo=20.0, tpot_slo=2.0),
)


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Seeded open-loop trace parameters. Everything downstream of
    ``seed`` is deterministic: same config -> identical trace, and (with
    the virtual clock) identical run report."""
    seed: int = 0
    n_requests: int = 64
    rate: float = 20.0             # mean arrivals / virtual second (Poisson)
    vocab: int = 64
    prompt_log_mu: float = math.log(12.0)   # lognormal prompt lengths
    prompt_log_sigma: float = 0.8
    prompt_max: int = 96
    out_log_mu: float = math.log(8.0)       # lognormal output lengths
    out_log_sigma: float = 0.7
    out_max: int = 32
    cancel_frac: float = 0.0       # fraction of requests cancelled mid-SLO
    # shared-prefix traffic (prefix-cache workloads): with probability
    # ``prefix_frac`` a request's prompt is one fixed per-trace "system
    # prompt" of ``prefix_len`` tokens followed by its own drawn body —
    # the overlap ratio knob the sharing benchmark sweeps. Defaults keep
    # traces byte-identical to pre-knob seeds (the extra RNG draws only
    # happen when the knob is on).
    prefix_len: int = 0
    prefix_frac: float = 0.0
    tiers: Tuple[TierSpec, ...] = DEFAULT_TIERS


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    uid: int
    arrival: float
    tier: str
    priority: int
    prompt: np.ndarray
    max_new_tokens: int
    deadline: float
    cancel_at: Optional[float] = None

    def request(self) -> Request:
        """A fresh Request for this entry (entries are reusable across
        runs; Requests are mutated by the engine)."""
        return Request(uid=self.uid, prompt=self.prompt.copy(),
                       max_new_tokens=self.max_new_tokens,
                       priority=self.priority, deadline=self.deadline)


def _clipped_lognormal(rng: np.random.Generator, mu: float, sigma: float,
                       hi: int) -> int:
    return int(np.clip(round(float(rng.lognormal(mu, sigma))), 1, hi))


def generate_trace(wcfg: WorkloadConfig) -> List[TraceEntry]:
    """Deterministic-per-seed open-loop trace (sorted by arrival)."""
    if not wcfg.tiers:
        raise ValueError("WorkloadConfig.tiers must not be empty")
    rng = np.random.default_rng(wcfg.seed)
    w = np.asarray([t.weight for t in wcfg.tiers], np.float64)
    w = w / w.sum()
    share = wcfg.prefix_len > 0 and wcfg.prefix_frac > 0
    shared_prefix = rng.integers(4, wcfg.vocab, size=wcfg.prefix_len) \
        .astype(np.int32) if share else None
    t = 0.0
    entries: List[TraceEntry] = []
    for uid in range(wcfg.n_requests):
        t += float(rng.exponential(1.0 / wcfg.rate))
        tier = wcfg.tiers[int(rng.choice(len(wcfg.tiers), p=w))]
        plen = _clipped_lognormal(rng, wcfg.prompt_log_mu,
                                  wcfg.prompt_log_sigma, wcfg.prompt_max)
        olen = _clipped_lognormal(rng, wcfg.out_log_mu,
                                  wcfg.out_log_sigma, wcfg.out_max)
        prompt = rng.integers(4, wcfg.vocab, size=plen).astype(np.int32)
        if share and float(rng.random()) < wcfg.prefix_frac:
            prompt = np.concatenate([shared_prefix, prompt])
        deadline = t + tier.ttft_slo + olen * tier.tpot_slo
        cancel_at = None
        if wcfg.cancel_frac > 0 and float(rng.random()) < wcfg.cancel_frac:
            # cancel somewhere inside the request's SLO window — the
            # client gave up (or navigated away) while being served
            cancel_at = t + float(rng.uniform(0.2, 0.9)) * (deadline - t)
        entries.append(TraceEntry(uid=uid, arrival=t, tier=tier.name,
                                  priority=tier.priority, prompt=prompt,
                                  max_new_tokens=olen, deadline=deadline,
                                  cancel_at=cancel_at))
    return entries


@dataclasses.dataclass(frozen=True)
class TickCostModel:
    """Virtual cost of one engine tick: a fixed dispatch overhead plus a
    per-processed-token term. Deliberately simple — the point is a
    *deterministic, monotone-in-work* clock, not a hardware model; wall
    mode exists for real timing."""
    base: float = 2e-3
    per_token: float = 5e-4

    def cost(self, tokens: int) -> float:
        return self.base + self.per_token * max(int(tokens), 0)


def _pct(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(math.ceil(q * len(ys))) - 1)]


@dataclasses.dataclass
class TierReport:
    name: str
    offered: int = 0               # requests in the trace
    done: int = 0                  # completed (any time)
    in_slo: int = 0                # completed by their deadline
    failed: Dict[str, int] = dataclasses.field(default_factory=dict)
    goodput_tokens: int = 0        # tokens of in-SLO completions
    delivered_tokens: int = 0      # all tokens handed back (incl. partial)
    ttft: List[float] = dataclasses.field(default_factory=list)
    # per-request time-per-output-token: (finish - first_token)/(n - 1)
    # for done requests with >= 2 tokens. Derived from request stamps,
    # not tick counts, so a speculative tick banking several tokens
    # lowers TPOT exactly as much as it should
    tpot: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft_p50(self) -> float:
        return _pct(self.ttft, 0.50)

    @property
    def ttft_p99(self) -> float:
        return _pct(self.ttft, 0.99)

    @property
    def tpot_p50(self) -> float:
        return _pct(self.tpot, 0.50)

    @property
    def tpot_p99(self) -> float:
        return _pct(self.tpot, 0.99)


@dataclasses.dataclass
class WorkloadReport:
    duration: float
    ticks: int
    goodput_tokens: int
    delivered_tokens: int
    tick_p50: float
    stall_p99: float               # p99 cost of ticks with a decoding row
    tiers: Dict[str, TierReport]
    # decode-phase aggregates: tokens BANKED on ticks that had a decoding
    # row, and those ticks' total cost — decode_time/decode_tokens is the
    # engine-level TPOT (equals stall-per-token only when every tick
    # banks exactly 1 token per row; speculation breaks that identity,
    # which is why this is tracked in tokens, not ticks)
    decode_tokens: int = 0
    decode_time: float = 0.0

    @property
    def goodput_tok_s(self) -> float:
        return self.goodput_tokens / self.duration if self.duration else 0.0

    @property
    def decode_tpot(self) -> float:
        return self.decode_time / self.decode_tokens \
            if self.decode_tokens else float("nan")

    def table(self) -> str:
        """CSV-ish per-tier summary (the benchmark prints this)."""
        lines = ["tier,offered,done,in_slo,shed,goodput_tok,"
                 "ttft_p50,ttft_p99,tpot_p50"]
        for tr in self.tiers.values():
            shed = sum(tr.failed.values())
            lines.append(f"{tr.name},{tr.offered},{tr.done},{tr.in_slo},"
                         f"{shed},{tr.goodput_tokens},{tr.ttft_p50:.3f},"
                         f"{tr.ttft_p99:.3f},{tr.tpot_p50:.4f}")
        lines.append(f"TOTAL goodput {self.goodput_tokens} tok "
                     f"({self.goodput_tok_s:.1f} tok/s virtual), delivered "
                     f"{self.delivered_tokens} tok, stall_p99 "
                     f"{self.stall_p99 * 1e3:.2f} ms, decode_tpot "
                     f"{self.decode_tpot * 1e3:.2f} ms/tok over "
                     f"{self.ticks} ticks")
        return "\n".join(lines)


def run_workload(batcher: ContinuousBatcher, trace: List[TraceEntry],
                 cost: TickCostModel = TickCostModel(),
                 wall_clock: bool = False,
                 max_ticks: int = 100_000) -> WorkloadReport:
    """Drive the engine through the trace open-loop. The runner owns the
    clock: it submits arrivals when the clock passes them, issues due
    cancellations, steps the engine with ``now`` and charges each tick
    ``cost.cost(tokens processed)`` (or measured wall time). When the
    engine is fully idle it jumps straight to the next arrival. The
    batcher is expected to be freshly constructed (its ``done``/``failed``
    lists become the report)."""
    pending = sorted(trace, key=lambda e: (e.arrival, e.uid))
    by_uid = {e.uid: e for e in trace}
    cancels = sorted(((e.cancel_at, e.uid) for e in trace
                      if e.cancel_at is not None))
    t = pending[0].arrival if pending else 0.0
    k = 0                      # next pending arrival
    c = 0                      # next cancellation
    ticks = 0
    tick_costs: List[float] = []
    stalls: List[float] = []
    decode_tokens = 0
    decode_time = 0.0
    while ticks < max_ticks:
        while k < len(pending) and pending[k].arrival <= t:
            batcher.submit(pending[k].request())
            k += 1
        while c < len(cancels) and cancels[c][0] <= t:
            batcher.cancel(cancels[c][1])
            c += 1
        live = any(s.req is not None for s in batcher.slots)
        if not live and not batcher.queue:
            if k >= len(pending):
                break                         # drained
            t = max(t, pending[k].arrival)    # idle: jump to next arrival
            continue
        decoding = any(s.req is not None and s.prefill is None
                       for s in batcher.slots)
        t0 = time.perf_counter()
        batcher.step(now=t)
        # the clock is charged for FED tokens (speculative drafts
        # included — the forward computed them whether or not they were
        # accepted); banked tokens feed the TPOT numerator below
        dt = time.perf_counter() - t0 if wall_clock \
            else cost.cost(batcher.last_tick_tokens)
        ticks += 1
        tick_costs.append(dt)
        if decoding:
            stalls.append(dt)
            decode_tokens += int(batcher.last_tick_new_tokens)
            decode_time += dt
        t += dt

    tiers: Dict[str, TierReport] = {}
    for e in trace:
        if e.tier not in tiers:
            tiers[e.tier] = TierReport(name=e.tier)
        tiers[e.tier].offered += 1
    goodput = delivered = 0
    for req in batcher.done:
        e = by_uid[req.uid]
        tr = tiers[e.tier]
        n = int(len(req.output))
        tr.done += 1
        tr.delivered_tokens += n
        delivered += n
        if req.finish_time is not None and req.finish_time <= e.deadline:
            tr.in_slo += 1
            tr.goodput_tokens += n
            goodput += n
        if req.first_token_time is not None:
            tr.ttft.append(req.first_token_time - e.arrival)
            if req.finish_time is not None and n >= 2:
                tr.tpot.append(
                    (req.finish_time - req.first_token_time) / (n - 1))
    for req in batcher.failed:
        e = by_uid.get(req.uid)
        if e is None:
            continue                      # chaos flood junk, not traced
        tr = tiers[e.tier]
        tr.failed[req.status] = tr.failed.get(req.status, 0) + 1
        n = 0 if req.output is None else int(len(req.output))
        tr.delivered_tokens += n
        delivered += n
    duration = (t - pending[0].arrival) if pending else 0.0
    return WorkloadReport(duration=duration, ticks=ticks,
                          goodput_tokens=goodput,
                          delivered_tokens=delivered,
                          tick_p50=_pct(tick_costs, 0.50),
                          stall_p99=_pct(stalls, 0.99),
                          tiers=tiers,
                          decode_tokens=decode_tokens,
                          decode_time=decode_time)
