from repro.train.losses import clm_loss, frame_loss, loss_for, mlm_loss
from repro.train.step import (
    TrainState,
    TrainTask,
    init_train_state,
    make_decode_step,
    make_eval_step,
    make_prefill_step,
    make_train_step,
)
from repro.train.loop import LoopConfig, evaluate, run_training

__all__ = [
    "clm_loss", "frame_loss", "loss_for", "mlm_loss",
    "TrainState", "TrainTask", "init_train_state", "make_decode_step",
    "make_eval_step", "make_prefill_step", "make_train_step",
    "LoopConfig", "evaluate", "run_training",
]
