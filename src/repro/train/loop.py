"""Training loop with outlier telemetry, checkpoint/restart and straggler
timing telemetry — the paper's pre-training protocol as a library function.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.outliers import OutlierStats
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.train.step import TrainState, TrainTask, init_train_state, make_eval_step, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 200
    eval_every: int = 100
    eval_batches: int = 8
    ckpt_every: int = 0              # 0 = disabled
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 20
    seed: int = 0
    # straggler telemetry: steps slower than `straggler_factor` x median are
    # counted and reported (on real fleets this feeds the re-scheduler)
    straggler_factor: float = 2.0


def run_training(
    task: TrainTask,
    data: SyntheticLM,
    loop: LoopConfig,
    batch_kind: str = "clm",
    log: Callable[[str], None] = print,
) -> Dict[str, object]:
    """Returns final state + history of losses/outlier metrics."""
    key = jax.random.PRNGKey(loop.seed)
    state = init_train_state(key, task)
    start_step = 0
    if loop.ckpt_dir and latest_step(loop.ckpt_dir) is not None:
        state, start_step = restore_checkpoint(loop.ckpt_dir, state)
        log(f"[resume] restored step {start_step} from {loop.ckpt_dir}")

    train_step = jax.jit(make_train_step(task), donate_argnums=(0,))
    eval_step = jax.jit(make_eval_step(task))

    history: Dict[str, List[float]] = {
        "step": [], "loss": [], "eval_ppl": [], "max_inf_norm": [], "kurtosis": [],
    }
    durations: List[float] = []
    stragglers = 0

    for step in range(start_step, loop.total_steps):
        batch = jax.tree_util.tree_map(jnp.asarray, data.batch(step, batch_kind))
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        metrics["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        durations.append(dt)
        if len(durations) > 10:
            med = float(np.median(durations[-100:]))
            if dt > loop.straggler_factor * med:
                stragglers += 1

        if loop.log_every and (step + 1) % loop.log_every == 0:
            log(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics.get('grad_norm', 0)):.2f} "
                f"max_act {float(metrics.get('max_act', 0)):.1f} {dt*1e3:.0f}ms")

        if loop.eval_every and (step + 1) % loop.eval_every == 0:
            ppl, ostats = evaluate(task, state.params, data, loop.eval_batches,
                                   batch_kind, eval_step)
            history["step"].append(step + 1)
            history["loss"].append(float(metrics["loss"]))
            history["eval_ppl"].append(ppl)
            history["max_inf_norm"].append(ostats["max_inf_norm"])
            history["kurtosis"].append(ostats["avg_kurtosis"])
            log(f"  eval ppl {ppl:.3f} inf_norm {ostats['max_inf_norm']:.1f} "
                f"kurtosis {ostats['avg_kurtosis']:.0f}")

        if loop.ckpt_every and loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            save_checkpoint(loop.ckpt_dir, step + 1, state, loop.keep_ckpts)

    if loop.ckpt_dir and loop.ckpt_every:
        save_checkpoint(loop.ckpt_dir, loop.total_steps, state, loop.keep_ckpts)

    return {
        "state": state,
        "history": history,
        "stragglers": stragglers,
        "median_step_s": float(np.median(durations)) if durations else 0.0,
    }


def evaluate(task: TrainTask, params, data: SyntheticLM, n_batches: int,
             batch_kind: str, eval_step=None, eval_offset: int = 10_000_000):
    """Perplexity + paper outlier metrics on held-out (offset) batches."""
    from repro.models.transformer import model_apply

    if eval_step is None:
        eval_step = jax.jit(make_eval_step(task))

    @jax.jit
    def acts_fn(p, batch):
        _, aux = model_apply(p, task.cfg, batch, collect_acts=True)
        return aux.get("attn_outputs", [])

    nll = tok = 0.0
    ostats = OutlierStats()
    for i in range(n_batches):
        batch = jax.tree_util.tree_map(
            jnp.asarray, data.batch(eval_offset + i, batch_kind))
        out = eval_step(params, batch)
        nll += float(out["nll"])
        tok += float(out["ntok"])
        acts = acts_fn(params, batch)
        if acts:
            ostats.update(acts)
    ppl = float(np.exp(nll / max(tok, 1.0)))
    return ppl, ostats.summary()
