"""Loss functions: causal LM (shifted), masked LM (ignore_index=-100),
frame classification (encoder heads). All return (sum_nll_f32, n_tokens)
so callers can aggregate exact perplexities across batches."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
IGNORE = -100


def _nll(logits: Array, labels: Array, valid: Array) -> Tuple[Array, Array]:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll), jnp.sum(valid)


def clm_loss(logits: Array, labels: Array) -> Tuple[Array, Array]:
    """Causal LM: predict token t+1 from logits at t."""
    lg = logits[:, :-1, :]
    lb = labels[:, 1:]
    valid = (lb != IGNORE).astype(jnp.float32)
    return _nll(lg, lb, valid)


def mlm_loss(logits: Array, labels: Array) -> Tuple[Array, Array]:
    """Masked LM: labels are -100 except at masked positions."""
    valid = (labels != IGNORE).astype(jnp.float32)
    return _nll(logits, labels, valid)


def frame_loss(logits: Array, labels: Array) -> Tuple[Array, Array]:
    """Per-frame classification over all positions (hubert-style)."""
    valid = (labels != IGNORE).astype(jnp.float32)
    return _nll(logits, labels, valid)


def loss_for(kind: str):
    return {"clm": clm_loss, "mlm": mlm_loss, "frames": frame_loss}[kind]
