"""Train / eval / serve step builders.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function suitable for ``jax.jit`` with shardings: under pjit+GSPMD the
gradient all-reduce across the (pod, data) axes is inserted by XLA from the
output shardings — no explicit psum needed (single-program SPMD).

Distributed-optimization knobs:
  * microbatching (gradient accumulation by ``lax.scan`` over splits),
  * int8 gradient compression + error feedback (cross-pod DP traffic /4),
  * donate-friendly: the caller donates ``state``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, model_apply
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.compress import ErrorFeedbackState, compress_grads, ef_init
from repro.optim.schedule import Schedule, constant
from repro.quant.qconfig import NO_QUANT
from repro.train.losses import loss_for

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Optional[ErrorFeedbackState]
    step: Array


@dataclasses.dataclass(frozen=True)
class TrainTask:
    cfg: ModelConfig
    loss_kind: str = "clm"            # clm | mlm | frames
    optimizer: AdamWConfig = AdamWConfig()
    schedule: Schedule = dataclasses.field(default_factory=constant)
    moe_lb_weight: float = 0.01
    moe_z_weight: float = 1e-3
    grad_compress: bool = False       # int8 + error feedback
    microbatch: int = 1               # gradient-accumulation splits


def init_train_state(key: Array, task: TrainTask) -> TrainState:
    from repro.models.transformer import model_init

    params = model_init(key, task.cfg)
    return TrainState(
        params=params,
        opt=adamw_init(params),
        ef=ef_init(params) if task.grad_compress else None,
        step=jnp.zeros((), jnp.int32),
    )


def _loss_and_metrics(params, task: TrainTask, batch) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = model_apply(params, task.cfg, batch)
    nll, ntok = loss_for(task.loss_kind)(logits, batch["labels"])
    loss = nll / jnp.maximum(ntok, 1.0)
    metrics = {"loss": loss, "ntok": ntok}
    moe = aux.get("moe_aux")
    if moe is not None and task.cfg.moe is not None:
        n_moe = max(task.cfg.n_layers, 1)
        lb = moe["load_balance"] / n_moe
        rz = moe["router_z"] / n_moe
        loss = loss + task.moe_lb_weight * lb + task.moe_z_weight * rz
        metrics.update(moe_lb=lb, moe_z=rz)
    if "act_stats" in aux:
        metrics["max_act"] = jnp.max(aux["act_stats"])
    return loss, metrics


def make_train_step(task: TrainTask) -> Callable:
    grad_fn = jax.value_and_grad(_loss_and_metrics, has_aux=True)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Array]]:
        if task.microbatch > 1:
            mb = task.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                (loss_acc, grads_acc) = carry
                (loss, metrics), grads = grad_fn(state.params, task, mbatch)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), metrics

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), metrics = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_grads), micro)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
            metrics["loss"] = loss
        else:
            (loss, metrics), grads = grad_fn(state.params, task, batch)

        ef = state.ef
        if task.grad_compress and ef is not None:
            grads, ef = compress_grads(grads, ef)

        lr_scale = task.schedule(state.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, task.optimizer, lr_scale)
        metrics.update(opt_metrics)
        metrics["lr_scale"] = lr_scale
        return TrainState(new_params, new_opt, ef, state.step + 1), metrics

    return train_step


def make_eval_step(task: TrainTask) -> Callable:
    def eval_step(params, batch) -> Dict[str, Array]:
        logits, aux = model_apply(params, task.cfg, batch)
        nll, ntok = loss_for(task.loss_kind)(logits, batch["labels"])
        out = {"nll": nll, "ntok": ntok}
        if "act_stats" in aux:
            out["max_act"] = jnp.max(aux["act_stats"])
        return out

    return eval_step


# --------------------------------------------------------------------------
# Serving steps (what decode_*/long_* cells lower)
# --------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, _ = model_apply(params, cfg, batch)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """One new token against an existing KV cache at position ``pos``."""

    def decode_step(params, cache, tokens, pos):
        logits, aux = model_apply(params, cfg, {"tokens": tokens},
                                  cache=cache, pos=pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], aux["cache"]

    return decode_step
