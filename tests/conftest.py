# Test-session configuration. Tests run on the default single CPU device;
# multi-device sharding tests spawn subprocesses with their own XLA_FLAGS
# (see test_sharding_dryrun.py).
#
# When `hypothesis` is not installed, a minimal stand-in is registered in
# sys.modules BEFORE test modules import it, so the property tests degrade
# to fixed-seed sampled cases (deterministic, capped example counts)
# instead of failing collection. Only the strategy surface this suite uses
# is implemented: given / settings / st.{integers,floats,booleans,
# sampled_from}.
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import inspect
    import types
    import zlib

    import numpy as _np

    _MAX_EXAMPLES_CAP = 8   # keep the degraded mode fast; hypothesis proper
    #                         runs the full max_examples when installed

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value=0, max_value=1 << 16):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _sampled_from(elements):
        elems = list(elements)
        return _Strategy(lambda rng: elems[int(rng.integers(0, len(elems)))])

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._mini_hyp_max_examples = max_examples
            return fn
        return deco

    def _given(**strategies):
        def deco(fn):
            n = min(getattr(fn, "_mini_hyp_max_examples", 10),
                    _MAX_EXAMPLES_CAP)
            seed = zlib.crc32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **dict(kwargs, **drawn))

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__wrapped__
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.__doc__ = "Fixed-seed fallback shim (hypothesis not installed)."
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

# --------------------------------------------------------------------------
# jit compile-count guard (repro.analysis.compile_guard). Registering the
# module as a plugin runs its pytest_configure (marker registration + jit
# tracking install) before test modules import repro.*, so every wrapper
# the suite creates is counted. The autouse fixture enforces
# @pytest.mark.compile_budget(n) budgets.
import pytest  # noqa: E402

pytest.register_assert_rewrite("repro.analysis.compile_guard")
pytest_plugins = ("repro.analysis.compile_guard",)

from repro.analysis.compile_guard import make_autouse_fixture  # noqa: E402

_compile_budget_guard = make_autouse_fixture(pytest)
