# Test-session configuration. Tests run on the default single CPU device;
# multi-device sharding tests spawn subprocesses with their own XLA_FLAGS
# (see test_sharding_dryrun.py).
