"""Contract linter (repro.analysis): per-rule true-positive + clean
fixtures, the call-graph scoping that keeps host-side code exempt,
suppression semantics (reasoned / reasonless), reporters and CLI exits.

Every fixture is linted in-memory via ``run_lint`` on (path, text) pairs;
paths are chosen to exercise the path-scoped rules (R003 only fires under
models//serving/, R005 under kernels/ or pallas importers).
"""
import json
import textwrap

import pytest

from repro.analysis import run_lint
from repro.analysis.engine import render_json, render_text
from repro.analysis.lint import main as lint_main


def lint(*sources):
    """sources: (path, code) pairs; returns the findings list."""
    findings, _ = run_lint(
        [(p, textwrap.dedent(code)) for p, code in sources])
    return findings


def active(findings, rule=None):
    return [f for f in findings if not f.suppressed
            and (rule is None or f.rule == rule)]


class TestR001HostSync:
    def test_true_positive_in_jitted_fn(self):
        fs = lint(("m.py", """
            import jax

            @jax.jit
            def step(x):
                return int(x.max())
        """))
        (f,) = active(fs, "R001")
        assert "int()" in f.message and "step" in f.message

    def test_true_positive_through_call_graph(self):
        """helper is only reachable via the jitted caller."""
        fs = lint(("m.py", """
            import jax

            def helper(v):
                return v.item()

            @jax.jit
            def outer(a):
                return helper(a)
        """))
        (f,) = active(fs, "R001")
        assert ".item()" in f.message and "helper" in f.message

    def test_clean_host_side_code(self):
        """The scheduler idiom: host code syncing AFTER a jitted call is
        fine — it is not jit-reachable."""
        fs = lint(("m.py", """
            import jax
            import numpy as np

            @jax.jit
            def step(x):
                return x * 2

            def drive(x):
                y = step(x)
                return int(y.max()), np.asarray(y)
        """))
        assert not active(fs, "R001")

    def test_clean_shape_access_kills_taint(self):
        """b, t = tokens.shape is static under tracing; int(t) is fine."""
        fs = lint(("m.py", """
            import jax

            @jax.jit
            def step(tokens):
                b, t = tokens.shape
                return tokens.reshape(int(b * t))
        """))
        assert not active(fs, "R001")

    def test_clean_annotated_python_params(self):
        """int/Config-annotated params are host values, not tracers."""
        fs = lint(("m.py", """
            import jax
            from functools import partial

            @partial(jax.jit, static_argnums=(1, 2))
            def step(x, width: int, cfg: ModelConfig):
                return x * int(width) * float(cfg.scale)
        """))
        assert not active(fs, "R001")


class TestR002StaticArgs:
    def test_true_positive_undeclared_static(self):
        fs = lint(("m.py", """
            import jax

            def f(x, width: int):
                return x * width

            step = jax.jit(f)
        """))
        (f,) = active(fs, "R002")
        assert "width" in f.message and "not declared static" in f.message

    def test_true_positive_unbucketed_shape(self):
        fs = lint(("m.py", """
            import numpy as np

            def tick(counts):
                t = int(counts.max())
                return np.zeros((4, t), np.int32)
        """))
        (f,) = active(fs, "R002")
        assert "shape" in f.message and "bucketing" in f.message

    def test_true_positive_unbucketed_static_arg(self):
        fs = lint(("m.py", """
            import jax

            def f(x, n):
                return x[:n]

            step = jax.jit(f, static_argnums=(1,))

            def tick(x, counts):
                return step(x, int(counts.max()))
        """))
        (f,) = active(fs, "R002")
        assert "static arg 1" in f.message

    def test_clean_bucketed(self):
        """The scheduler's real pattern: _bucket() wrapping makes both the
        shape use and the static-arg use bounded."""
        fs = lint(("m.py", """
            import jax
            import numpy as np

            def _bucket(n):
                return 1 if n <= 1 else 1 << (n - 1).bit_length()

            def f(x, n):
                return x[:n]

            step = jax.jit(f, static_argnums=(1,))

            def tick(x, counts):
                t = _bucket(int(counts.max()))
                buf = np.zeros((4, t), np.int32)
                return step(x, t)
        """))
        assert not active(fs, "R002")

    def test_clean_declared_statics(self):
        fs = lint(("m.py", """
            import jax

            def f(x, width: int, causal: bool):
                return x * width

            step = jax.jit(f, static_argnums=(1, 2))
        """))
        assert not active(fs, "R002")


class TestR003MaskedScatter:
    def test_true_positive_unguarded_cache_write(self):
        fs = lint(("src/repro/serving/s.py", """
            def write(cache, idx, v):
                cache["k"] = cache["k"].at[idx].set(v)
                return cache
        """))
        (f,) = active(fs, "R003")
        assert "jnp.where" in f.message and 'mode="drop"' in f.message

    def test_true_positive_guard_without_drop(self):
        fs = lint(("src/repro/models/m.py", """
            import jax.numpy as jnp

            def write(cache, idx, v, act):
                idx = jnp.where(act, idx, -1)
                cache["k"] = cache["k"].at[idx].set(v)
                return cache
        """))
        (f,) = active(fs, "R003")
        assert 'mode="drop" is missing' in f.message
        assert "jnp.where" not in f.message.split(":")[1].split(" and ")[0]

    def test_clean_masked_write(self):
        """The model_apply contract verbatim."""
        fs = lint(("src/repro/models/m.py", """
            import jax.numpy as jnp

            def write(cache, widx, v, act):
                widx = jnp.where(act, widx, 4096)
                cache["k"] = cache["k"].at[:, widx].set(v, mode="drop")
                return cache
        """))
        assert not active(fs, "R003")

    def test_out_of_scope_paths_exempt(self):
        """Same write outside models//serving/ (e.g. an optimizer state
        pool in train/) is not this contract."""
        fs = lint(("src/repro/train/t.py", """
            def write(pool_cache, idx, v):
                pool_cache = pool_cache.at[idx].set(v)
                return pool_cache
        """))
        assert not active(fs, "R003")


class TestR004Prng:
    def test_true_positive_double_draw(self):
        fs = lint(("m.py", """
            import jax

            def sample(key, shape):
                a = jax.random.normal(key, shape)
                b = jax.random.uniform(key, shape)
                return a + b
        """))
        (f,) = active(fs, "R004")
        assert "reused without split/fold_in" in f.message

    def test_true_positive_loop_reuse(self):
        fs = lint(("m.py", """
            import jax

            def sample(key, xs):
                out = []
                for x in xs:
                    out.append(jax.random.normal(key, x.shape))
                return out
        """))
        (f,) = active(fs, "R004")
        assert "loop" in f.message

    def test_clean_split_between_draws(self):
        fs = lint(("m.py", """
            import jax

            def sample(key, shape):
                a = jax.random.normal(key, shape)
                key, sub = jax.random.split(key)
                b = jax.random.uniform(sub, shape)
                return a + b
        """))
        assert not active(fs, "R004")

    def test_clean_fold_in_loop(self):
        """The serving position-keyed idiom."""
        fs = lint(("m.py", """
            import jax

            def sample(key, xs):
                out = []
                for i, x in enumerate(xs):
                    k = jax.random.fold_in(key, i)
                    out.append(jax.random.normal(k, x.shape))
                return out
        """))
        assert not active(fs, "R004")


class TestR005Pallas:
    def test_true_positive_traced_index_map_capture(self):
        fs = lint(("src/repro/kernels/k.py", """
            import jax.experimental.pallas as pl
            import jax.numpy as jnp

            def launch(x, table):
                t = table.astype(jnp.int32)
                spec = pl.BlockSpec((8, 8), lambda i, j: (t[i], j))
                return spec
        """))
        (f,) = active(fs, "R005")
        assert "closes over `t`" in f.message
        assert "scalar prefetch" in f.fixit

    def test_true_positive_dynamic_ref_slice(self):
        fs = lint(("src/repro/kernels/k.py", """
            def kernel(x_ref, o_ref, n):
                o_ref[0:n] = x_ref[0:n] * 2.0
        """))
        assert len(active(fs, "R005")) == 2  # both refs flagged

    def test_clean_shape_derived_index_map(self):
        """The paged-attention kernel's real shape: the closure captures
        only values derived via .shape."""
        fs = lint(("src/repro/kernels/k.py", """
            import jax.experimental.pallas as pl

            def launch(x, table):
                nb = table.shape[1]

                def kv_index(bi, wi):
                    return (bi * nb + wi, 0)

                spec = pl.BlockSpec((8, 8), kv_index)
                return spec
        """))
        assert not active(fs, "R005")

    def test_clean_static_and_pl_ds_indexing(self):
        fs = lint(("src/repro/kernels/k.py", """
            import jax.experimental.pallas as pl

            def kernel(x_ref, o_ref, i):
                o_ref[0:4] = x_ref[0:4]
                o_ref[0, i, :] = x_ref[0, i, :]
                x_ref[pl.ds(i * 8, 8)]
        """))
        assert not active(fs, "R005")


class TestSuppressions:
    SRC = """
        import numpy as np

        def tick(counts):
            t = int(counts.max())  {comment}
            return np.zeros((4, t), np.int32)
    """

    def test_reasoned_suppression_silences(self):
        fs = lint(("m.py", self.SRC.format(
            comment="# repro: ignore[R002] exact length required here")))
        assert not active(fs)
        (sup,) = [f for f in fs if f.suppressed]
        assert sup.suppress_reason == "exact length required here"

    def test_reasonless_suppression_rejected(self):
        fs = lint(("m.py", self.SRC.format(comment="# repro: ignore[R002]")))
        # original finding stays active AND an R000 flags the bare ignore
        assert active(fs, "R002")
        assert any(f.rule == "R000" and "no reason" in f.message
                   for f in fs)

    def test_wrong_rule_id_does_not_suppress(self):
        fs = lint(("m.py", self.SRC.format(
            comment="# repro: ignore[R001] not the firing rule")))
        assert active(fs, "R002")

    def test_suppression_on_preceding_line(self):
        fs = lint(("m.py", """
            import numpy as np

            def tick(counts):
                # repro: ignore[R002] exact length required here
                t = int(counts.max())
                return np.zeros((4, t), np.int32)
        """))
        assert not active(fs)


class TestReportersAndCli:
    BAD = """
        import jax

        @jax.jit
        def step(x):
            return int(x.max())
    """

    def test_json_reporter_shape(self):
        fs = lint(("m.py", self.BAD))
        doc = json.loads(render_json(fs))
        assert doc["active"] == 1 and doc["suppressed"] == 0
        (j,) = doc["findings"]
        assert j["rule"] == "R001" and j["path"] == "m.py"
        assert j["line"] >= 1 and j["fixit"]

    def test_text_reporter_counts(self):
        fs = lint(("m.py", self.BAD))
        txt = render_text(fs)
        assert "1 finding(s), 0 suppressed" in txt
        assert "m.py:" in txt and "fix:" in txt

    def test_syntax_error_is_finding_not_crash(self):
        fs = lint(("m.py", "def broken(:\n"))
        (f,) = active(fs, "R000")
        assert "syntax error" in f.message

    def test_cli_exit_codes(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent(self.BAD))
        ok = tmp_path / "ok.py"
        ok.write_text("x = 1\n")
        assert lint_main([str(bad)]) == 1
        assert lint_main([str(ok)]) == 0
        assert lint_main([str(tmp_path / "missing.py")]) == 2
        assert lint_main([str(bad), "--rules", "R999"]) == 2
        assert lint_main([str(bad), "--format", "json"]) == 1
        capsys.readouterr()

    def test_repo_tree_is_clean(self):
        """The acceptance gate CI enforces: src/ lints clean."""
        assert lint_main(["src/"]) == 0


class TestRuleCatalogue:
    def test_five_rules_active_with_contracts(self):
        from repro.analysis import ALL_RULES
        ids = [r.id for r in ALL_RULES]
        assert ids == ["R001", "R002", "R003", "R004", "R005"]
        for cls in ALL_RULES:
            r = cls()
            assert r.title and r.contract
