"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step on CPU, asserting output shapes and no NaNs; plus
decode-cache consistency and scan-vs-unroll equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import apply_method, get_arch, list_archs
from repro.models import init_cache, model_apply, model_init
from repro.optim import AdamWConfig
from repro.train import TrainTask, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # arch-pool sweep: dozens of reduced-width model compiles

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = list_archs()


def _batch(cfg, b=2, t=16):
    if cfg.input_kind == "tokens":
        return {"tokens": jax.random.randint(KEY, (b, t), 0, cfg.vocab_size),
                "labels": jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)}
    if cfg.input_kind == "embeds":
        return {"embeds": jax.random.normal(KEY, (b, t, cfg.frontend_dim)),
                "labels": jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)}
    n = cfg.n_prefix_embeds
    return {"embeds": jax.random.normal(KEY, (b, n, cfg.d_model)),
            "tokens": jax.random.randint(KEY, (b, t - n), 0, cfg.vocab_size),
            "labels": jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)}


def test_all_ten_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("method", ["vanilla", "clipped_softmax",
                                    "gated_attention"])
def test_forward_smoke(arch, method):
    cfg = apply_method(get_arch(arch).smoke(), method)
    params = model_init(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = model_apply(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_arch(arch).smoke()
    loss_kind = "clm" if cfg.causal else "frames"
    task = TrainTask(cfg=cfg, loss_kind=loss_kind,
                     optimizer=AdamWConfig(lr=1e-3))
    state = init_train_state(KEY, task)
    step = jax.jit(make_train_step(task))
    batch = jax.tree_util.tree_map(jnp.asarray, _batch(cfg))
    state, m = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m["loss"]) + 1.0  # sane update


@pytest.mark.parametrize("arch", ["deepseek-67b", "gemma2-27b",
                                  "recurrentgemma-9b", "xlstm-1.3b",
                                  "granite-moe-1b-a400m", "qwen3-14b"])
def test_decode_cache_consistency(arch):
    cfg = dataclasses.replace(get_arch(arch).smoke(), max_seq_len=32)
    params = model_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    full, _ = model_apply(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 2, 12)
    outs = []
    for t in range(12):
        lg, aux = model_apply(params, cfg, {"tokens": toks[:, t:t + 1]},
                              cache=cache, pos=t)
        cache = aux["cache"]
        outs.append(lg)
    np.testing.assert_allclose(full, jnp.concatenate(outs, axis=1), atol=5e-3)


@pytest.mark.parametrize("arch", ["deepseek-67b", "gemma2-27b", "xlstm-1.3b",
                                  "recurrentgemma-9b"])
def test_scan_matches_unroll(arch):
    cfg = get_arch(arch).smoke()
    cfg_s = dataclasses.replace(cfg, scan_layers=True, remat=True)
    params_u = model_init(KEY, cfg)
    params_s = model_init(KEY, cfg_s)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    lu, _ = model_apply(params_u, cfg, {"tokens": toks})
    ls, _ = model_apply(params_s, cfg_s, {"tokens": toks})
    np.testing.assert_allclose(lu, ls, atol=3e-4)


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    expect = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 49155),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 151936),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 32064),
        "deepseek-67b": (95, 8192, 64, 8, 102400),
        "gemma2-27b": (46, 4608, 32, 16, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 151936),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 92416),
        "recurrentgemma-9b": (38, 4096, 16, 1, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 50304),
        "hubert-xlarge": (48, 1280, 16, 16, 504),
    }
    for arch, (nl, dm, h, kv, v) in expect.items():
        cfg = get_arch(arch).full()
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.vocab_size) == (nl, dm, h, kv, v), arch


def test_moe_expert_counts():
    g = get_arch("granite-moe-1b-a400m").full().moe
    assert (g.n_experts, g.top_k, g.d_ff) == (32, 8, 512)
    q = get_arch("qwen2-moe-a2.7b").full().moe
    assert (q.n_experts, q.top_k, q.d_ff, q.n_shared_experts) == (60, 4, 1408, 4)


def test_skip_list_documented():
    long_runners = [a for a in ALL_ARCHS
                    if get_arch(a).skipped("long_500k") is None]
    assert sorted(long_runners) == ["recurrentgemma-9b", "xlstm-1.3b"]
    assert get_arch("hubert-xlarge").skipped("decode_32k") is not None
