"""Dense vs chunked attention equivalence across the paper's softmax
variants, GQA, local windows, soft-caps, decode offsets."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionConfig, chunked_attention, dense_attention
from repro.core.softmax import ClippedSoftmaxConfig

KEY = jax.random.PRNGKey(0)


def _qkv(b=2, t=96, h=8, hkv=4, d=16, tk=None):
    tk = tk or t
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (b, t, h, d)),
            jax.random.normal(ks[1], (b, tk, hkv, d)),
            jax.random.normal(ks[2], (b, tk, hkv, d)))


SOFTMAXES = [
    ClippedSoftmaxConfig(),
    ClippedSoftmaxConfig(gamma=-0.03),
    ClippedSoftmaxConfig(gamma=-0.01, zeta=1.03),
    ClippedSoftmaxConfig(alpha=4.0),
]


@pytest.mark.parametrize("sm", SOFTMAXES)
@pytest.mark.parametrize("window", [None, 24])
def test_dense_vs_chunked(sm, window):
    q, k, v = _qkv()
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16, causal=True,
                          window=window, softmax=sm, chunk_size=32)
    np.testing.assert_allclose(
        dense_attention(q, k, v, cfg), chunked_attention(q, k, v, cfg),
        atol=3e-5)


def test_bidirectional_and_softcap():
    q, k, v = _qkv()
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16, causal=False,
                          logit_softcap=30.0,
                          softmax=ClippedSoftmaxConfig(gamma=-0.02),
                          chunk_size=40)
    np.testing.assert_allclose(
        dense_attention(q, k, v, cfg), chunked_attention(q, k, v, cfg),
        atol=3e-5)


def test_decode_offset_matches_full():
    """q_offset decode slice reproduces the corresponding full-attn rows."""
    q, k, v = _qkv(t=32)
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16, causal=True,
                          softmax=ClippedSoftmaxConfig(gamma=-0.03))
    full = dense_attention(q, k, v, cfg)
    last = dense_attention(q[:, 31:32], k, v, cfg, q_offset=31)
    np.testing.assert_allclose(full[:, 31:32], last, atol=1e-5)


def test_gate_pi_scales_output():
    q, k, v = _qkv(t=16)
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16)
    pi = jnp.full((2, 16, 8), 0.5)
    base = dense_attention(q, k, v, cfg)
    gated = dense_attention(q, k, v, cfg, gate_pi=pi)
    np.testing.assert_allclose(gated, 0.5 * base, atol=1e-6)


def test_clipped_rows_not_normalized():
    """Clipped softmax rows may sum < 1 (the no-op capability)."""
    q, k, v = _qkv(t=8)
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16,
                          softmax=ClippedSoftmaxConfig(gamma=-0.5))
    out = dense_attention(q, k * 0 + 10.0, v, cfg)
    assert not bool(jnp.any(jnp.isnan(out)))
