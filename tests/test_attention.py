"""Dense vs chunked attention equivalence across the paper's softmax
variants, GQA, local windows, soft-caps, decode offsets."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionConfig, chunked_attention, dense_attention
from repro.core.softmax import ClippedSoftmaxConfig

KEY = jax.random.PRNGKey(0)


def _qkv(b=2, t=96, h=8, hkv=4, d=16, tk=None):
    tk = tk or t
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (b, t, h, d)),
            jax.random.normal(ks[1], (b, tk, hkv, d)),
            jax.random.normal(ks[2], (b, tk, hkv, d)))


SOFTMAXES = [
    ClippedSoftmaxConfig(),
    ClippedSoftmaxConfig(gamma=-0.03),
    ClippedSoftmaxConfig(gamma=-0.01, zeta=1.03),
    ClippedSoftmaxConfig(alpha=4.0),
]


@pytest.mark.parametrize("sm", SOFTMAXES)
@pytest.mark.parametrize("window", [None, 24])
def test_dense_vs_chunked(sm, window):
    q, k, v = _qkv()
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16, causal=True,
                          window=window, softmax=sm, chunk_size=32)
    np.testing.assert_allclose(
        dense_attention(q, k, v, cfg), chunked_attention(q, k, v, cfg),
        atol=3e-5)


def test_bidirectional_and_softcap():
    q, k, v = _qkv()
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16, causal=False,
                          logit_softcap=30.0,
                          softmax=ClippedSoftmaxConfig(gamma=-0.02),
                          chunk_size=40)
    np.testing.assert_allclose(
        dense_attention(q, k, v, cfg), chunked_attention(q, k, v, cfg),
        atol=3e-5)


def test_decode_offset_matches_full():
    """q_offset decode slice reproduces the corresponding full-attn rows."""
    q, k, v = _qkv(t=32)
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16, causal=True,
                          softmax=ClippedSoftmaxConfig(gamma=-0.03))
    full = dense_attention(q, k, v, cfg)
    last = dense_attention(q[:, 31:32], k, v, cfg, q_offset=31)
    np.testing.assert_allclose(full[:, 31:32], last, atol=1e-5)


def test_gate_pi_scales_output():
    q, k, v = _qkv(t=16)
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16)
    pi = jnp.full((2, 16, 8), 0.5)
    base = dense_attention(q, k, v, cfg)
    gated = dense_attention(q, k, v, cfg, gate_pi=pi)
    np.testing.assert_allclose(gated, 0.5 * base, atol=1e-6)


def test_dispatcher_routing(monkeypatch):
    """Pin `attention`'s dense/chunked routing. Regression for the
    precedence trap `... or tq == 1 and tk <= 8192` (the `and` bound
    tighter than intended reads suggested): decode with a long KV axis must
    stream, not materialize (Tq, Tk)."""
    import sys

    import repro.core.attention  # noqa: F401 — repro.core re-exports the
    A = sys.modules["repro.core.attention"]  # fn `attention`, shadowing it

    routed = []
    monkeypatch.setattr(A, "dense_attention",
                        lambda *a, **k: routed.append("dense"))
    monkeypatch.setattr(A, "chunked_attention",
                        lambda *a, **k: routed.append("chunked"))
    cfg = AttentionConfig(n_heads=1, n_kv_heads=1, d_head=4)

    def route(tq, tk, force_dense=False):
        routed.clear()
        q = jnp.zeros((1, tq, 1, 4))
        kv = jnp.zeros((1, tk, 1, 4))
        A.attention(q, kv, kv, cfg, force_dense=force_dense)
        return routed[0]

    assert route(1, 512) == "dense"          # decode, short KV
    assert route(1, 8192) == "dense"         # decode, at the dense cap
    assert route(1, 8193) == "chunked"       # decode, long KV -> stream
    assert route(64, 512) == "dense"         # small prefill
    assert route(2048, 2048) == "dense"      # at the inner dense cap
    assert route(3000, 3000) == "chunked"    # mid region streams
    assert route(8192, 8192) == "chunked"    # large prefill streams
    assert route(8192, 8192, force_dense=True) == "dense"


def test_clipped_rows_not_normalized():
    """Clipped softmax rows may sum < 1 (the no-op capability)."""
    q, k, v = _qkv(t=8)
    cfg = AttentionConfig(n_heads=8, n_kv_heads=4, d_head=16,
                          softmax=ClippedSoftmaxConfig(gamma=-0.5))
    out = dense_attention(q, k * 0 + 10.0, v, cfg)
    assert not bool(jnp.any(jnp.isnan(out)))
