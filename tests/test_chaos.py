"""Fault injection: >= 5 distinct seeded fault plans run with zero
crashes and zero allocator-audit violations; randomized preemption-storm
recovery leaves survivors token-exact vs an unpreempted oracle (fp and
int8-KV); transient alloc faults stall-and-recover without shedding;
persistent faults shed strictly in priority order."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import (
    ChaosHarness,
    ContinuousBatcher,
    FaultPlan,
    FaultyAllocator,
    GenerateConfig,
    Request,
    generate,
)

KEY = jax.random.PRNGKey(0)
JUNK0 = ChaosHarness.JUNK_UID0


def _setup(max_len=64):
    cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                              max_seq_len=max_len)
    return cfg, model_init(KEY, cfg)


def _ref(params, cfg, prompt, m):
    return np.asarray(generate(params, cfg, jnp.asarray(prompt)[None, :],
                               GenerateConfig(max_new_tokens=m))[0,
                                                                 len(prompt):])


def _requests(n, seed, max_prompt=16, max_new=6):
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(2, max_prompt + 1))
        reqs.append(Request(
            uid=uid, prompt=rng.integers(4, 60, size=plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, max_new + 1)),
            priority=int(rng.integers(0, 3))))
    return reqs


def _chaos_batcher(params, cfg, **kw):
    base = dict(batch_size=3, max_len=64, token_budget=32, paged=True,
                block_size=4, num_blocks=24, swap_break_even_tokens=8,
                on_pool_exhausted="shed", debug_audit=True)
    base.update(kw)
    return ContinuousBatcher(params, cfg, **base)


def test_fault_plans_are_seeded_and_distinct():
    plans = [FaultPlan.random(s, ticks=40) for s in range(5)]
    again = [FaultPlan.random(s, ticks=40) for s in range(5)]
    assert plans == again                       # deterministic per seed
    assert len({p for p in plans}) == 5         # and genuinely distinct
    assert any(p.alloc_fail for p in plans)
    assert any(p.preempt_storm for p in plans)
    assert any(p.flood for p in plans)
    assert any(p.swap_deny for p in plans)


def test_five_plans_no_crash_no_audit_violation_survivors_exact():
    """The acceptance gate: 5 distinct seeded plans against an int8-KV
    paged engine — ChaosHarness audits after every tick, so reaching the
    end at all means zero crashes and zero audit violations. On top, every
    traced request that completed must be token-exact vs the oracle:
    storms, floods, swaps, and denials may delay work, never corrupt it."""
    cfg, params = _setup()
    reqs = _requests(8, seed=42)
    oracle = {r.uid: _ref(params, cfg, r.prompt, r.max_new_tokens)
              for r in reqs}
    for seed in range(5):
        plan = FaultPlan.random(seed, ticks=20)
        b = _chaos_batcher(params, cfg, kv_int8=True)
        for r in reqs:
            b.submit(dataclasses.replace(
                r, prompt=r.prompt.copy(), output=None))
        h = ChaosHarness(b, plan)
        h.run()
        b.audit()
        for req in b.done:
            if req.uid >= JUNK0:
                continue
            # int8 engine vs fp oracle differ; exactness is vs the int8
            # unperturbed run — checked in the storm tests below. Here:
            # completed means full-length, uncorrupted bookkeeping.
            assert len(req.output) == req.max_new_tokens
            assert req.status == "done"
        for req in b.failed:
            assert req.status in ("shed", "cancelled", "expired", "timeout")
        assert b.allocator.available == b.num_blocks


def _storm_outputs(params, cfg, reqs, kv_int8, storm_seed):
    b = _chaos_batcher(params, cfg, kv_int8=kv_int8,
                       on_pool_exhausted="raise")
    for r in reqs:
        b.submit(dataclasses.replace(r, prompt=r.prompt.copy(), output=None))
    rng = np.random.default_rng(storm_seed)
    ticks = 0
    while (b.queue or any(s.req is not None for s in b.slots)) \
            and ticks < 500:
        # randomized admit/preempt/resume/cancel interleaving
        if rng.random() < 0.3:
            live = [i for i, s in enumerate(b.slots) if s.req is not None]
            if live:
                b.preempt_slot(int(rng.choice(live)))
        if rng.random() < 0.1:
            cancellable = [r.uid for r in b.queue] + \
                [s.req.uid for s in b.slots if s.req is not None]
            if cancellable:
                b.cancel(int(rng.choice(cancellable)))
        b.step()
        b.audit()
        ticks += 1
    assert ticks < 500, "storm failed to drain"
    assert b.allocator.available == b.num_blocks
    return b


def test_preemption_storm_survivors_exact_fp():
    cfg, params = _setup()
    reqs = _requests(6, seed=1)
    # unpreempted oracle on an identical engine
    ob = _chaos_batcher(params, cfg, on_pool_exhausted="raise")
    for r in reqs:
        ob.submit(dataclasses.replace(r, prompt=r.prompt.copy(), output=None))
    while ob.queue or any(s.req is not None for s in ob.slots):
        ob.step()
    oracle = {r.uid: r.output for r in ob.done}
    for storm_seed in (0, 1):
        b = _storm_outputs(params, cfg, reqs, False, storm_seed)
        assert b.done, "storm cancelled everything (seed too hostile)"
        for req in b.done:
            np.testing.assert_array_equal(
                req.output, oracle[req.uid],
                err_msg=f"storm={storm_seed} uid={req.uid}")
        for req in b.failed:
            assert req.status == "cancelled"


def test_preemption_storm_survivors_exact_int8():
    cfg, params = _setup()
    reqs = _requests(6, seed=2)
    ob = _chaos_batcher(params, cfg, kv_int8=True, on_pool_exhausted="raise")
    for r in reqs:
        ob.submit(dataclasses.replace(r, prompt=r.prompt.copy(), output=None))
    while ob.queue or any(s.req is not None for s in ob.slots):
        ob.step()
    oracle = {r.uid: r.output for r in ob.done}
    b = _storm_outputs(params, cfg, reqs, True, storm_seed=0)
    assert b.done
    for req in b.done:
        np.testing.assert_array_equal(req.output, oracle[req.uid],
                                      err_msg=f"uid={req.uid}")


def test_transient_alloc_fault_recovers_without_shedding():
    """Alloc denials on ticks 2-4 while blocks genuinely exist: the
    engine must stall the affected rows (transient-fault policy), resume
    when the fault clears, complete everything, and shed nothing."""
    cfg, params = _setup()
    reqs = _requests(4, seed=9, max_prompt=10)
    oracle = {r.uid: _ref(params, cfg, r.prompt, r.max_new_tokens)
              for r in reqs}
    b = _chaos_batcher(params, cfg, on_pool_exhausted="raise")
    b.allocator = FaultyAllocator(b.allocator)
    for r in reqs:
        b.submit(dataclasses.replace(r, prompt=r.prompt.copy(), output=None))
    for t in range(200):
        b.allocator.failing = 2 <= t <= 4
        b.step()
        b.audit()
        if not b.queue and all(s.req is None for s in b.slots):
            break
    assert b.allocator.denied > 0, "fault window never bit"
    assert not b.failed
    assert len(b.done) == len(reqs)
    for req in b.done:
        np.testing.assert_array_equal(req.output, oracle[req.uid])


def test_persistent_fault_sheds_in_priority_order():
    """Under a never-clearing alloc fault no row can make progress; after
    the bounded retry streak the engine must shed load strictly lowest
    priority first until nothing is left — and never crash."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prios = [2, 2, 1, 0, 1, 0]
    b = _chaos_batcher(params, cfg, batch_size=2, fault_shed_after=3,
                       on_pool_exhausted="raise")
    b.allocator = FaultyAllocator(b.allocator)
    b.allocator.failing = True
    for uid, p in enumerate(prios):
        b.submit(Request(
            uid=uid, prompt=rng.integers(4, 60, size=6).astype(np.int32),
            max_new_tokens=4, priority=p))
    for _ in range(120):
        b.step()
        b.audit()
        if not b.queue and all(s.req is None for s in b.slots):
            break
    assert not b.done
    shed = [r for r in b.failed if r.status == "shed"]
    assert len(shed) == len(prios)
    shed_prios = [r.priority for r in shed]
    assert shed_prios == sorted(shed_prios), \
        f"sheds out of priority order: {shed_prios}"
