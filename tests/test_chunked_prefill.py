"""Token-budget prefill engine: chunked-vs-one-shot bitwise equivalence at
the model level (dense + paged + ring caches, clipped/gated, chunk sizes
that do and don't divide the prompt), mixed prefill+decode ticks vs the
sequential oracle, preemption-resume-through-chunks under sampling seeds,
and the (priority, arrival) + watermark admission policy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import apply_method
from repro.models import model_init
from repro.models.transformer import (
    ModelConfig,
    init_cache,
    init_paged_cache,
    model_apply,
)
from repro.serving import ContinuousBatcher, GenerateConfig, Request, generate

KEY = jax.random.PRNGKey(0)


def _tiny(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                d_ff=64, vocab_size=64, pos="rope", max_seq_len=1024,
                scan_layers=False, remat=False, mlp_kind="swiglu",
                norm="rmsnorm")
    base.update(kw)
    return ModelConfig(**base)


def _refs(params, cfg, prompts, max_new):
    return [np.asarray(generate(params, cfg, jnp.asarray(p)[None, :],
                                GenerateConfig(max_new_tokens=m))[0, len(p):])
            for p, m in zip(prompts, max_new)]


def _ref_free(params, cfg, prompt, max_new):
    """Cache-free greedy oracle (works where generate's one-shot ring
    prefill cannot: local_attn prompts longer than the window)."""
    seq = list(map(int, prompt))
    out = []
    for _ in range(max_new):
        logits, _ = model_apply(params, cfg,
                                {"tokens": jnp.asarray([seq], jnp.int32)})
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        seq.append(tok)
    return np.asarray(out, np.int32)


def _chunked(params, cfg, cache, prompt, sizes, pad_to=None):
    """Stream ``prompt`` through ``model_apply`` in chunks of ``sizes``
    using the scheduler's contract: per-row pos vector + per-token active
    mask dropping the padding tail. Returns (last real token's logits,
    final cache)."""
    off, last = 0, None
    for c in sizes:
        t = pad_to or c
        buf = np.zeros((1, t), np.int32)
        buf[0, :c] = prompt[off:off + c]
        act = np.zeros((1, t), bool)
        act[0, :c] = True
        logits, aux = model_apply(params, cfg, {"tokens": jnp.asarray(buf)},
                                  cache=cache,
                                  pos=jnp.asarray([off], jnp.int32),
                                  active=jnp.asarray(act))
        cache = aux["cache"]
        last = np.asarray(logits[0, c - 1])
        off += c
    return last, cache


def _fresh_cache(cfg, paged):
    if not paged:
        return init_cache(cfg, 1, 32)
    cache = init_paged_cache(cfg, 1, 32, num_blocks=6, block_size=8)
    table = jnp.asarray([[2, 0, 3, -1]], jnp.int32)   # scrambled physical

    def set_table(path, leaf):
        if path and path[-1] == jax.tree_util.DictKey("block_table"):
            return jnp.broadcast_to(table, leaf.shape[:-2] + table.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(set_table, cache)


CHUNKINGS = ([4, 4, 4], [5, 5, 2], [7, 5])    # dividing and non-dividing


class TestChunkedVsOneShot:
    """Chunked prefill must be BITWISE equal to one-shot: the cache state
    after streaming N chunks and the final token's logits are identical to
    feeding the whole prompt at once — the slice-invariance contract that
    keeps gamma = -alpha/T clipping and activation ranges stable across
    serving-path changes."""

    def _check(self, cfg, paged=False):
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(1)
        prompt = rng.integers(4, 60, size=12).astype(np.int32)
        ref_last, ref_cache = _chunked(params, cfg, _fresh_cache(cfg, paged),
                                       prompt, [12])
        for sizes in CHUNKINGS:
            last, cache = _chunked(params, cfg, _fresh_cache(cfg, paged),
                                   prompt, sizes, pad_to=8)
            np.testing.assert_array_equal(last, ref_last, err_msg=str(sizes))
            for (pa, a), (pb, bb) in zip(
                    jax.tree_util.tree_leaves_with_path(ref_cache),
                    jax.tree_util.tree_leaves_with_path(cache)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(bb),
                    err_msg=f"{sizes} {jax.tree_util.keystr(pa)}")

    def test_dense_vanilla(self):
        self._check(_tiny())

    def test_dense_clipped(self):
        self._check(apply_method(_tiny(), "clipped_softmax", alpha=4.0))

    def test_dense_gated(self):
        self._check(apply_method(_tiny(), "gated_attention", pi_init=0.5))

    def test_paged_clipped(self):
        self._check(apply_method(_tiny(max_seq_len=64), "clipped_softmax",
                                 alpha=4.0), paged=True)

    def test_paged_gated(self):
        self._check(apply_method(_tiny(max_seq_len=64), "gated_attention",
                                 pi_init=0.5), paged=True)

    def test_ring_clipped(self):
        """local_attn chunks attend over the PRE-write ring + fresh chunk
        (separate KV entries), so multi-token writes cannot evict history
        earlier queries of the same chunk still need — and the nonzero
        summands keep their logical order, so equality stays bitwise.
        alpha-resolved gamma must pin to the RING length, not the
        chunk-size-dependent concat axis (L + T), or clipping thresholds
        drift with the chunking. init_std=0.5 keeps attention probs spread
        enough that clipping genuinely engages (at tiny init every prob
        clips to zero and the gamma assertions would be vacuous)."""
        cfg = apply_method(
            _tiny(pattern=("attn", "local_attn"), window=8, max_seq_len=64,
                  init_std=0.5),
            "clipped_softmax", alpha=4.0)
        self._check(cfg)
        # non-vacuity guards: on these params clipping changes the output
        # (vs vanilla) and the output is sensitive to gamma
        from repro.core.softmax import ClippedSoftmaxConfig
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(1)
        prompt = rng.integers(4, 60, size=12).astype(np.int32)
        ref, _ = _chunked(params, cfg, init_cache(cfg, 1, 32), prompt, [12])
        for sm in (ClippedSoftmaxConfig(), ClippedSoftmaxConfig(gamma=-10.0)):
            alt_cfg = dataclasses.replace(cfg, softmax_cfg=sm)
            alt, _ = _chunked(params, alt_cfg, init_cache(alt_cfg, 1, 32),
                              prompt, [12])
            assert not np.array_equal(alt, ref), sm


class TestLongRingPrompt:
    """Acceptance: a prompt longer than the local_attn window is admitted
    via chunked prefill and its generated tokens exactly match the
    cache-free oracle — the capability the seed's one-shot ring limit
    (a ValueError at admission / a RuntimeError at preemption) blocked."""

    @pytest.mark.parametrize("kw", [
        dict(batch_size=2, max_len=32),
        dict(batch_size=2, max_len=32, paged=True, block_size=8),
        dict(batch_size=2, max_len=32, token_budget=5),
        dict(batch_size=2, max_len=32, paged=True, block_size=8,
             token_budget=5),
    ])
    def test_long_prompt_matches_oracle(self, kw):
        cfg = _tiny(pattern=("attn", "local_attn"), window=8, max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(5)
        prompt = rng.integers(4, 60, size=20).astype(np.int32)   # 20 > 8
        ref = _ref_free(params, cfg, prompt, 6)
        b = ContinuousBatcher(params, cfg, **kw)
        b.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        np.testing.assert_array_equal(b.run()[0].output, ref, err_msg=str(kw))


class TestStandaloneChunkedGenerate:
    """ROADMAP carryover: standalone ``generate`` routes long prompts
    through the batcher's chunked-prefill contract (``step_rows`` with
    uniform pos/count vectors), so a ``local_attn`` prompt longer than the
    window works outside the engine — and produces exactly the engine's
    tokens."""

    def _ring_cfg(self):
        return _tiny(pattern=("attn", "local_attn"), window=8,
                     max_seq_len=64)

    def test_long_local_prompt_matches_oracle(self):
        """The seed's one-shot ring prefill could not admit 20 > window=8;
        chunked generate must, and must match the cache-free oracle."""
        cfg = self._ring_cfg()
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(11)
        prompt = rng.integers(4, 60, size=20).astype(np.int32)
        ref = _ref_free(params, cfg, prompt, 6)
        out = generate(params, cfg, jnp.asarray(prompt)[None, :],
                       GenerateConfig(max_new_tokens=6))
        np.testing.assert_array_equal(np.asarray(out[0, 20:]), ref)

    def test_matches_engine_bitwise(self):
        """Same chunk boundaries as the engine (token_budget == window ==
        ring cap -> chunks 8, 8, 4): generated ids must agree exactly."""
        cfg = self._ring_cfg()
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(12)
        prompt = rng.integers(4, 60, size=20).astype(np.int32)
        out = generate(params, cfg, jnp.asarray(prompt)[None, :],
                       GenerateConfig(max_new_tokens=6))
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=32,
                              token_budget=8)
        b.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        engine = b.run()[0].output
        np.testing.assert_array_equal(np.asarray(out[0, 20:]), engine)

    def test_explicit_chunking_matches_oneshot(self):
        """On a non-ring config chunked prefill is opt-in; forcing it must
        not change the greedy continuation vs the one-shot path."""
        cfg = _tiny()
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(13)
        prompt = jnp.asarray(rng.integers(4, 60, size=(2, 11)), jnp.int32)
        gen = GenerateConfig(max_new_tokens=8)
        ref = generate(params, cfg, prompt, gen)
        chunked = generate(params, cfg, prompt, gen, prefill_chunk=4)
        np.testing.assert_array_equal(np.asarray(chunked), np.asarray(ref))


class TestMixedTick:
    """Acceptance: one forward pass carries >= 2 prefill chunks from
    different requests AND an actively decoding row, and every request
    still emits exactly the sequential oracle's tokens."""

    @pytest.mark.parametrize("paged", [False, True])
    def test_mixed_tick_matches_oracle(self, paged):
        cfg, _ = _tiny(), None
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (4, 10, 9)]
        max_new = [10, 5, 5]
        refs = _refs(params, cfg, prompts, max_new)
        kw = dict(paged=True, block_size=8) if paged else {}
        b = ContinuousBatcher(params, cfg, batch_size=3, max_len=32,
                              token_budget=8, prefill_chunk=4, **kw)
        b.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=max_new[0]))
        assert b.step() == 1                      # uid 0 prefills + samples
        # uid 0 is now decoding; two long prompts arrive together
        b.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=max_new[1]))
        b.submit(Request(uid=2, prompt=prompts[2], max_new_tokens=max_new[2]))
        assert b.step() == 3
        counts = np.sort(b.last_counts)[::-1]
        # one decode token + two chunks (budget 8 - 1 decode = 7 -> 4 + 3)
        assert counts[0] > 1 and counts[1] > 1 and counts[2] == 1, counts
        out = {r.uid: r.output for r in b.run()}
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")

    def test_empty_prompt_rejected_at_submit(self):
        """A zero-length prompt has no logits position to sample from; it
        must be rejected up front, not wedge the planner (regression: it
        used to stall forever and crash dense mode through the paged-only
        pool-too-small path)."""
        cfg = _tiny()
        params = model_init(KEY, cfg)
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=16)
        with pytest.raises(ValueError, match="empty prompt"):
            b.submit(Request(uid=0, prompt=np.asarray([], np.int32),
                             max_new_tokens=4))

    def test_budget_bounds_tick_tokens(self):
        """Every sub-step's carved token count respects the budget."""
        cfg = _tiny()
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(9)
        b = ContinuousBatcher(params, cfg, batch_size=3, max_len=32,
                              token_budget=4)
        for u in range(3):
            b.submit(Request(uid=u, prompt=rng.integers(
                4, 60, size=10).astype(np.int32), max_new_tokens=3))
        while b.queue or any(s.req for s in b.slots):
            n_decode = sum(1 for s in b.slots
                           if s.req is not None and s.prefill is None)
            b.step()
            if b.last_counts is not None:
                # decode rows are never starved; prefill carving fills the rest
                assert b.last_counts.sum() <= max(b.token_budget, n_decode)


class TestRecurrentUniformSteps:
    @pytest.mark.parametrize("token_budget", [256, 4])
    def test_griffin_batcher_matches_oracle(self, token_budget):
        """Recurrent configs run split decode/uniform-prefill sub-steps
        (ragged rows are inexpressible for a recurrence) with the EXACT
        chunk length — a padded tail would stream garbage through the
        recurrence. budget=4 additionally chunks the prompts, carrying
        h/conv state across chunks (a capability the one-shot engine never
        exercised)."""
        from repro.nn.recurrent import RGLRUConfig
        cfg = _tiny(pattern=("griffin", "attn"), max_seq_len=64,
                    rglru=RGLRUConfig(width=32, conv_width=4))
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (9, 5, 7)]
        refs = [_ref_free(params, cfg, p, 5) for p in prompts]
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32,
                              token_budget=token_budget)
        for u, p in enumerate(prompts):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=5))
        out = {r.uid: r.output for r in b.run()}
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")


class TestPreemptResumeChunks:
    @pytest.mark.slow
    def test_sampled_preemption_past_window_resumes_exactly(self):
        """Recompute-preemption of rows PAST the local_attn window (refused
        by the seed engine) under temperature sampling: the resume re-enters
        the chunked prefill path and position-keyed draws reproduce the
        continuation exactly."""
        cfg = _tiny(pattern=("attn", "local_attn"), window=8, max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(2)]

        def run(**kw):
            b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32,
                                  gen=GenerateConfig(temperature=0.8, top_k=16),
                                  paged=True, block_size=4, **kw)
            for u, p in enumerate(prompts):
                b.submit(Request(uid=u, prompt=p, max_new_tokens=12,
                                 seed=100 + u))
            return {r.uid: r.output for r in b.run()}

        roomy = run()
        tight = run(num_blocks=6)    # both rows stall past the window
        for u in roomy:
            np.testing.assert_array_equal(tight[u], roomy[u],
                                          err_msg=f"uid={u}")


class TestAdmissionPolicy:
    def _reqs(self, rng, n, prio):
        return [Request(uid=u, prompt=rng.integers(4, 60, size=4)
                        .astype(np.int32), max_new_tokens=2,
                        priority=prio[u]) for u in range(n)]

    def test_priority_order_beats_fifo(self):
        """Higher priority admits first regardless of submission order;
        equal priorities stay FIFO by arrival (no starvation reordering)."""
        cfg = _tiny()
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(3)
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=16)
        for r in self._reqs(rng, 5, prio=[0, 0, 5, 0, 5]):
            b.submit(r)
        b.run()
        admitted = [r.uid for r in sorted(b.done, key=lambda r: r.arrival)]
        assert admitted == [0, 1, 2, 3, 4]          # bookkeeping sanity
        # completion order == admission order at batch_size 1
        assert [r.uid for r in b.done] == [2, 4, 0, 1, 3]

    def test_equal_priority_is_starvation_free(self):
        """With equal priorities the queue is exactly FIFO: a request can
        never be overtaken by a later equal-priority arrival."""
        cfg = _tiny()
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(3)
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=16)
        for r in self._reqs(rng, 6, prio=[1] * 6):
            b.submit(r)
        b.run()
        assert [r.uid for r in b.done] == list(range(6))

    def test_watermark_defers_admission(self):
        """Paged admission halts while free_blocks < admit_watermark and
        resumes once retirement replenishes the pool."""
        cfg = _tiny(max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(4)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(2)]
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32,
                              paged=True, block_size=4, num_blocks=8,
                              admit_watermark=7)
        b.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=4))
        b.step()                   # uid 0 prefills, holds 2 blocks
        b.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=4))
        b.step()
        # available = 6 < watermark 7: uid 1 must wait despite a free slot
        assert sum(s.req is not None for s in b.slots) == 1
        assert len(b.queue) == 1
        out = {r.uid: r.output for r in b.run()}
        assert sorted(out) == [0, 1]                # admitted after retire
        refs = _refs(params, cfg, prompts, [4, 4])
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")

    def test_preempted_request_keeps_arrival_rank(self):
        """A preempted request re-queues at its ORIGINAL arrival rank, so
        it re-admits ahead of later equal-priority arrivals."""
        cfg = _tiny(max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(11)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(3)]
        max_new = [12, 12, 12]
        refs = _refs(params, cfg, prompts, max_new)
        # 6-block pool: uids 0/1 grow to 5 blocks each -> uid 1 (youngest)
        # is preempted, freeing its slot; uid 2 arrived later at the same
        # priority and must not overtake the re-queued uid 1 for it
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32,
                              paged=True, block_size=4, num_blocks=6)
        for u, (p, m) in enumerate(zip(prompts, max_new)):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=m))
        seen_second_occupant = set()
        while b.queue or any(s.req for s in b.slots):
            b.step()
            for s in b.slots:
                if s.req is not None and s.req.uid != 0:
                    seen_second_occupant.add(s.req.uid)
        out = {r.uid: r.output for r in b.done}
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")
        finished = [r.uid for r in b.done]
        # uid 1 re-admits (and so finishes) ahead of the later arrival
        assert finished.index(1) < finished.index(2)
        assert 1 in seen_second_occupant and 2 in seen_second_occupant
