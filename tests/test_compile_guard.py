"""Recompile-regression tripwire (repro.analysis.compile_guard): the
decode tick's jit specializations stay within the pow-2 bucket budget as
live widths grow, and the guard FAILS when an unbucketed static arg is
introduced into the tick — the runtime complement of lint rule R002.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.scheduler as scheduler
from repro.analysis.compile_guard import (CompileBudgetExceeded,
                                          CompileGuard, track)
from repro.models import model_init
from repro.models.transformer import ModelConfig
from repro.serving import ContinuousBatcher, Request, SpecConfig
from repro.serving.scheduler import _bucket

KEY = jax.random.PRNGKey(0)


def _tiny():
    return ModelConfig(name="tiny", n_layers=1, d_model=16, n_heads=2,
                       n_kv_heads=2, d_ff=32, vocab_size=32, pos="rope",
                       max_seq_len=64, scan_layers=False, remat=False,
                       mlp_kind="swiglu", norm="rmsnorm")


class TestGuardMechanics:
    def test_counts_compiles_per_shape(self):
        f = jax.jit(lambda x: x * 2)
        track(f)
        with CompileGuard() as guard:
            f(jnp.zeros((2,)))
            f(jnp.zeros((2,)))   # cache hit
            f(jnp.zeros((3,)))   # new shape
        assert guard.compiles == 2

    def test_raises_over_budget(self):
        f = jax.jit(lambda x, n: x[:n], static_argnums=(1,))
        track(f)
        x = jnp.arange(16)
        with pytest.raises(CompileBudgetExceeded, match="budget is 2"):
            with CompileGuard(budget=2):
                for n in (3, 5, 6, 7):      # unbucketed: 4 compiles
                    f(x, n)

    def test_bucketing_stays_within_budget(self):
        f = jax.jit(lambda x, n: x[:n], static_argnums=(1,))
        track(f)
        x = jnp.arange(16)
        with CompileGuard(budget=3) as guard:
            for n in (3, 5, 6, 7):          # buckets: 4, 8 -> 2 compiles
                f(x, _bucket(n))
        assert guard.compiles == 2

    def test_marker_enforces_budget(self, testdir=None):
        """The pytest marker path: run a mini-suite where one test blows
        its budget and assert pytest reports the failure."""
        f = jax.jit(lambda x: x + 1)
        track(f)
        with CompileGuard(budget=0):
            pass                            # zero-compile body passes
        with pytest.raises(CompileBudgetExceeded):
            with CompileGuard(budget=0):
                f(jnp.zeros((4,)))


@pytest.mark.compile_budget(8)
def test_decode_tick_sweep_within_pow2_budget():
    """Drive the paged decode tick until a row's block count has crossed
    several pow-2 boundaries (held blocks 1 -> ~14). The static
    (t_step, live_width) pair the tick feeds jax.jit must take at most:
    1 prefill variant + one decode variant per pow-2 bucket (1, 2, 4, 8,
    16) = 6 compiles. The @compile_budget(8) marker enforces it with
    slack for platform variation; an unbucketed live width would need one
    compile per distinct block count (~14) and trip the budget."""
    cfg = _tiny()
    params = model_init(KEY, cfg)
    b = ContinuousBatcher(params, cfg, batch_size=1, max_len=32,
                          paged=True, block_size=2, num_blocks=20)
    prompt = np.arange(2, 4, dtype=np.int32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=25))
    out = b.run()[0].output
    assert out.shape == (25,)
    # the sweep genuinely crossed buckets: ticks saw widths 1 and >8
    assert _bucket(14) == 16


@pytest.mark.compile_budget(6)
def test_spec_tick_sweep_within_pow2_budget():
    """Speculative tick: per-tick Tq = 1 + draft length reaches jax.jit
    as a static arg only after pow-2 bucketing. A stub drafter cycles
    draft lengths 0..k so successive ticks sweep every Tq in 1..k+1;
    bucketed, that is one program per pow-2 bucket ({1, 2, 4, 8} for
    k=7), and the prefill chunk (T=2) reuses the T=2 program — the spec
    step is ONE program family, not a per-draft-length zoo. Unbucketed
    Tq would need a compile per distinct draft length (~8) and trip the
    budget."""
    cfg = _tiny()
    params = model_init(KEY, cfg)
    # block_size == max_len: every row holds exactly one block, so the
    # live-width static stays 1 and the sweep isolates the Tq axis
    b = ContinuousBatcher(params, cfg, batch_size=1, max_len=64,
                          paged=True, block_size=64, num_blocks=4,
                          spec=SpecConfig(k=7))

    class _CycleDrafter:
        calls = 0

        def propose(self, prompt, generated, k):
            self.calls += 1
            return [1] * min((self.calls - 1) % 8, k)

    b._drafter = _CycleDrafter()
    b.submit(Request(uid=0, prompt=np.arange(2, 4, dtype=np.int32),
                     max_new_tokens=30))
    out = b.run()[0].output
    assert out.shape == (30,)
    assert b._drafter.calls > 8  # the cycle wrapped: every Tq was fed


def test_unbucketed_static_arg_trips_guard(monkeypatch):
    """Acceptance demo: replace the scheduler's pow-2 bucketing with the
    identity (exactly the regression R002 lints against) and the SAME
    sweep blows the compile budget the bucketed tick satisfies."""
    monkeypatch.setattr(scheduler, "_bucket", lambda n: max(int(n), 1))
    cfg = _tiny()
    params = model_init(KEY, cfg)
    b = ContinuousBatcher(params, cfg, batch_size=1, max_len=32,
                          paged=True, block_size=2, num_blocks=20)
    track(b._step_fn)
    b.submit(Request(uid=0, prompt=np.arange(2, 4, dtype=np.int32),
                     max_new_tokens=25))
    with pytest.raises(CompileBudgetExceeded):
        with CompileGuard(budget=8):
            b.run()
