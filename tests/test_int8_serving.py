"""Hardware-path int8 serving: the int8 weight cache and linear pieces,
the quantized paged KV pool (per-slot scale roundtrip, partial tail
blocks, equal-memory admission capacity), and the W8A8 engine tick's
compile-count guard. Token-level quality/agreement lives in
test_int8_serving_quality.py; the fp scheduler itself is covered by
test_serving_engine.py / test_chunked_prefill.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.models.transformer import (
    init_paged_cache,
    model_apply,
    paged_kv_block_bytes,
)
from repro.quant import QConfig, kv_dequant, kv_quant
from repro.quant.int8_weights import build_int8_cache, int8_cache_bytes, linear_int8
from repro.serving.scheduler import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


class TestInt8WeightCache:
    def test_cache_covers_matmuls_and_skips_head(self):
        cfg = opt_tiny(vocab=128, seq_len=32)
        params = model_init(KEY, cfg)
        cache = build_int8_cache(params)
        assert any("/q/w" in p for p in cache)
        assert any("/mlp/up/w" in p for p in cache)
        assert not any("lm_head" in p for p in cache)
        # int8 cache is ~4x smaller than f32 weights it replaces
        f32_bytes = sum(
            np.prod(np.asarray(v[0].shape)) * 4 for v in cache.values())
        assert int8_cache_bytes(cache) * 3.9 < f32_bytes

    def test_int8_linear_matches_float_within_quant_error(self):
        cfg = opt_tiny(vocab=128, seq_len=32)
        params = model_init(KEY, cfg)
        cache = build_int8_cache(params)
        path = next(p for p in cache if p.endswith("/q/w"))
        # locate the float weight
        from repro.nn.module import flatten_params
        w = dict(flatten_params(params))[path]
        x = jax.random.normal(KEY, (4, 8, w.shape[0]))
        y_int8 = linear_int8(cache, path, x)
        y_fp = x @ w
        rel = float(jnp.mean(jnp.abs(y_int8 - y_fp)) / jnp.mean(jnp.abs(y_fp)))
        assert rel < 0.05, rel


class TestContinuousBatcher:
    def _setup(self, B=3):
        cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                                  max_seq_len=64)
        params = model_init(KEY, cfg)
        return ContinuousBatcher(params, cfg, batch_size=B, max_len=64)

    def test_all_requests_complete(self):
        b = self._setup()
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i, prompt=rng.integers(4, 64, size=5).astype(np.int32),
                        max_new_tokens=6) for i in range(5)]
        for r in reqs:
            b.submit(r)
        done = b.run()
        assert len(done) == 5
        for r in done:
            assert r.output is not None and len(r.output) == 6

    def test_outputs_match_unbatched_decode(self):
        """A scheduled request decodes the same tokens as a dedicated
        single-sequence generate (cache-row isolation)."""
        from repro.serving import GenerateConfig, generate
        b = self._setup(B=2)
        prompt = np.arange(4, 10, dtype=np.int32)
        b.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
        b.submit(Request(uid=1, prompt=prompt[::-1].copy(), max_new_tokens=5))
        done = sorted(b.run(), key=lambda r: r.uid)
        ref = generate(b.params, b.cfg, jnp.asarray(prompt)[None, :],
                       GenerateConfig(max_new_tokens=5))
        np.testing.assert_array_equal(done[0].output,
                                      np.asarray(ref[0, len(prompt):]))

    def test_slots_refill_from_queue(self):
        b = self._setup(B=2)
        rng = np.random.default_rng(1)
        for i in range(4):   # 4 requests through 2 slots
            b.submit(Request(uid=i,
                             prompt=rng.integers(4, 64, 4).astype(np.int32),
                             max_new_tokens=3))
        done = b.run()
        assert len(done) == 4


def _small_cfg(**kw):
    base = dataclasses.replace(opt_tiny(vocab=64, seq_len=32), n_layers=2,
                               d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
                               d_ff=256, max_seq_len=64)
    return dataclasses.replace(base, **kw)


class TestInt8KVPool:
    """The quantized paged KV pool in isolation: per-slot scale roundtrip
    and the fused quantize-on-scatter against the fp pool oracle."""

    def test_roundtrip_error_bounded_by_half_step(self):
        """Property (seeded sweep over magnitudes 1e-4..1e3): dequant(
        quant(x)) is within half a quantization step of x per (block,
        slot), and the stored scale is exactly amax/127 (clamped)."""
        rng = np.random.default_rng(0)
        for i in range(20):
            mag = 10.0 ** rng.uniform(-4, 3)
            x = (rng.standard_normal((5, 8, 2, 16)) * mag).astype(np.float32)
            q, s = kv_quant(jnp.asarray(x))
            assert q.dtype == jnp.int8 and s.shape == (5, 8)
            amax = np.abs(x).max(axis=(-2, -1))
            np.testing.assert_allclose(np.asarray(s),
                                       np.maximum(amax / 127.0, 1e-8),
                                       rtol=1e-6, err_msg=f"iter {i}")
            err = np.abs(np.asarray(kv_dequant(q, s)) - x)
            half_step = np.asarray(s)[..., None, None] * 0.5
            assert np.all(err <= half_step + 1e-7 * mag), f"iter {i}"

    def test_zero_slots_keep_eps_scale(self):
        q, s = kv_quant(jnp.zeros((2, 4, 2, 8)))
        assert not np.asarray(q).any()
        np.testing.assert_allclose(np.asarray(s), 1e-8, rtol=1e-6)
        assert not np.asarray(kv_dequant(q, s)).any()

    def test_partial_tail_block_scales(self):
        """Write 5 tokens into an 8-slot block through the model's masked
        scatter (scrambled physical table): written slots dequantize to
        the fp pool within half a step, their scales are per-TOKEN amax
        (not a block-wide max), and unwritten slots keep zero codes and
        zero scales."""
        cfg = _small_cfg(max_seq_len=16)
        params = model_init(KEY, cfg)
        tokens = jnp.asarray([[5, 9, 17, 33, 2]], jnp.int32)
        table = jnp.asarray([[2, 0]], jnp.int32)

        def run(kv_int8):
            cache = init_paged_cache(cfg, 1, 16, num_blocks=3, block_size=8,
                                     kv_int8=kv_int8)

            def set_table(path, leaf):
                if path and path[-1] == jax.tree_util.DictKey("block_table"):
                    return jnp.broadcast_to(table, leaf.shape)
                return leaf

            cache = jax.tree_util.tree_map_with_path(set_table, cache)
            _, aux = model_apply(params, cfg, {"tokens": tokens}, cache=cache,
                                 pos=jnp.asarray([0], jnp.int32),
                                 active=jnp.ones((1, 5), bool))
            return {jax.tree_util.keystr(p): np.asarray(leaf) for p, leaf
                    in jax.tree_util.tree_leaves_with_path(aux["cache"])}

        fp, i8 = run(False), run(True)
        scale_paths = [p for p in i8 if p.endswith("'k_scale']")
                       or p.endswith("'v_scale']")]
        assert scale_paths, "no int8 attn pools in the cache"
        tight_checked = 0
        for sp in scale_paths:
            pool_p = sp.replace("_scale", "")
            q, s = i8[pool_p], i8[sp]
            assert q.dtype == np.int8
            # tail slots of the written block + both unwritten blocks
            assert not q[2, 5:].any() and not s[2, 5:].any(), sp
            assert not q[[0, 1]].any() and not s[[0, 1]].any(), sp
            if "'layers'][0" not in sp:
                # deeper layers see inputs already perturbed by layer 0's
                # KV dequant, so the fp pool is no longer a tight oracle
                continue
            ref = fp[pool_p]
            # tokens 0..4 land in physical block 2 (table[0] == 2)
            got = q[2, :5].astype(np.float32) * s[2, :5, None, None]
            err = np.abs(got - ref[2, :5])
            assert np.all(err <= s[2, :5, None, None] * 0.5 + 1e-7), sp
            amax = np.abs(ref[2, :5]).max(axis=(-2, -1))
            np.testing.assert_allclose(s[2, :5], np.maximum(amax / 127, 1e-8),
                                       rtol=1e-5, err_msg=sp)
            tight_checked += 1
        assert tight_checked == 2    # layer 0's k_scale and v_scale


class TestInt8KVCapacity:
    """ROADMAP item #1's capacity claim, measured with the same byte
    accounting the pools allocate (paged_kv_block_bytes): at equal pool
    memory the int8 engine concurrently advances ~3x the rows of fp
    (asserted >= 1.8x; f32 pools shrink ~3.5x, bf16 ~2x)."""

    @pytest.mark.compile_budget(24)
    def test_equal_memory_admits_2x_rows(self):
        cfg = _small_cfg()
        params = model_init(KEY, cfg)
        bs = 8
        budget = 12 * paged_kv_block_bytes(cfg, bs, kv_int8=False)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(4, 64, 25).astype(np.int32) for _ in range(8)]

        def peak_rows(kv_int8):
            nb = budget // paged_kv_block_bytes(cfg, bs, kv_int8=kv_int8)
            b = ContinuousBatcher(params, cfg, batch_size=8, max_len=32,
                                  paged=True, block_size=bs, num_blocks=nb,
                                  kv_int8=kv_int8)
            for u, p in enumerate(prompts):
                b.submit(Request(uid=u, prompt=p, max_new_tokens=2))
            peak = ticks = 0
            while (b.queue or any(s.req is not None for s in b.slots)) \
                    and ticks < 500:
                b.step()
                ticks += 1
                peak = max(peak, sum(1 for s in b.slots if s.blocks))
            assert len(b.done) == 8
            return peak, nb

        peak_fp, nb_fp = peak_rows(False)
        peak_i8, nb_i8 = peak_rows(True)
        # each row needs 4 blocks (25-token prompt + 2 decodes, block 8)
        assert peak_fp == nb_fp // 4, (peak_fp, nb_fp)
        assert nb_i8 >= 1.8 * nb_fp, (nb_i8, nb_fp)
        assert peak_i8 >= 1.8 * peak_fp, (peak_i8, peak_fp)
        assert peak_i8 == 8          # the whole batch fits at equal memory


class TestInt8EngineTick:
    """The W8A8 + int8-KV tick is guarded against jit-specialization
    explosions exactly like the fp tick (test_compile_guard)."""

    @pytest.mark.compile_budget(10)
    def test_int8_tick_sweep_within_pow2_budget(self):
        """Decode across several pow-2 live-width boundaries on the full
        int8 engine: calibration + weight quantization happen once at
        construction (eager, zero tracked compiles), and the tick takes at
        most one variant per (phase, pow-2 bucket) — the same budget shape
        as the fp sweep in test_compile_guard."""
        cfg = _small_cfg()
        params = model_init(KEY, cfg)
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=32,
                              paged=True, block_size=2, num_blocks=20,
                              qconfig=QConfig())
        assert b.kv_int8    # defaults on for a paged qconfig engine
        b.submit(Request(uid=0, prompt=np.arange(2, 4, dtype=np.int32),
                         max_new_tokens=25))
        out = b.run()[0].output
        assert out.shape == (25,)
