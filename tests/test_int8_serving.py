"""Hardware-path int8 serving + continuous-batching scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.quant.int8_weights import build_int8_cache, int8_cache_bytes, linear_int8
from repro.serving.scheduler import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


class TestInt8WeightCache:
    def test_cache_covers_matmuls_and_skips_head(self):
        cfg = opt_tiny(vocab=128, seq_len=32)
        params = model_init(KEY, cfg)
        cache = build_int8_cache(params)
        assert any("/q/w" in p for p in cache)
        assert any("/mlp/up/w" in p for p in cache)
        assert not any("lm_head" in p for p in cache)
        # int8 cache is ~4x smaller than f32 weights it replaces
        f32_bytes = sum(
            np.prod(np.asarray(v[0].shape)) * 4 for v in cache.values())
        assert int8_cache_bytes(cache) * 3.9 < f32_bytes

    def test_int8_linear_matches_float_within_quant_error(self):
        cfg = opt_tiny(vocab=128, seq_len=32)
        params = model_init(KEY, cfg)
        cache = build_int8_cache(params)
        path = next(p for p in cache if p.endswith("/q/w"))
        # locate the float weight
        from repro.nn.module import flatten_params
        w = dict(flatten_params(params))[path]
        x = jax.random.normal(KEY, (4, 8, w.shape[0]))
        y_int8 = linear_int8(cache, path, x)
        y_fp = x @ w
        rel = float(jnp.mean(jnp.abs(y_int8 - y_fp)) / jnp.mean(jnp.abs(y_fp)))
        assert rel < 0.05, rel


class TestContinuousBatcher:
    def _setup(self, B=3):
        cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                                  max_seq_len=64)
        params = model_init(KEY, cfg)
        return ContinuousBatcher(params, cfg, batch_size=B, max_len=64)

    def test_all_requests_complete(self):
        b = self._setup()
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i, prompt=rng.integers(4, 64, size=5).astype(np.int32),
                        max_new_tokens=6) for i in range(5)]
        for r in reqs:
            b.submit(r)
        done = b.run()
        assert len(done) == 5
        for r in done:
            assert r.output is not None and len(r.output) == 6

    def test_outputs_match_unbatched_decode(self):
        """A scheduled request decodes the same tokens as a dedicated
        single-sequence generate (cache-row isolation)."""
        from repro.serving import GenerateConfig, generate
        b = self._setup(B=2)
        prompt = np.arange(4, 10, dtype=np.int32)
        b.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
        b.submit(Request(uid=1, prompt=prompt[::-1].copy(), max_new_tokens=5))
        done = sorted(b.run(), key=lambda r: r.uid)
        ref = generate(b.params, b.cfg, jnp.asarray(prompt)[None, :],
                       GenerateConfig(max_new_tokens=5))
        np.testing.assert_array_equal(done[0].output,
                                      np.asarray(ref[0, len(prompt):]))

    def test_slots_refill_from_queue(self):
        b = self._setup(B=2)
        rng = np.random.default_rng(1)
        for i in range(4):   # 4 requests through 2 slots
            b.submit(Request(uid=i,
                             prompt=rng.integers(4, 64, 4).astype(np.int32),
                             max_new_tokens=3))
        done = b.run()
        assert len(done) == 4
