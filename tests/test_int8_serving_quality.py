"""Serving-quality harness for end-to-end INT8 serving — the paper's
Table 2 story, live: greedy outputs of the W8A8 + int8-KV batcher vs the
fp engine, across vanilla / clipped-softmax / gated-attention and
dense / paged (gather oracle + Pallas kernel) backends.

Metric design (why trained models + injected outliers):

* Greedy token agreement on RANDOM-INIT models is a coin flip — logits
  are flat, so fp-vs-int8 argmax agreement sits near chance for every
  config and the paper's contrast is invisible. The fixture therefore
  TRAINS each tiny model for a few hundred steps on the synthetic Markov
  chain; the chain's top-1 transition is deterministic, so a converged
  model has decisive argmax margins and an outlier-free model survives
  W8A8 + int8-KV serving with agreement ~1.0.
* Tiny models trained for seconds never GROW the paper's outliers, so the
  "vanilla at scale" condition is simulated structurally: a few fc1
  output channels are amplified by M with the matching fc2 rows scaled by
  1/M. Since relu(M·x) = M·relu(x) for M > 0 the fp function is exactly
  unchanged — but the per-tensor activation range at the fc2 input
  explodes by ~M, which is precisely the outlier→range failure chain
  (PAPER.md Fig. 1; Wei et al., 2022). The amplified channels vary per
  token (unlike a scaled embedding column, whose constant residual
  direction acts as an argmax attractor and paradoxically *stabilizes*
  int8 agreement), so the injection degrades serving the way real
  outliers do.

Also here: bitwise invariance of int8-KV serving to chunk size, slot
assignment, and preemption-resume — same oracles as test_chunked_prefill,
now with quantize-on-write pools (each token's int8 code + scale are a
pure function of (value, logical position); see quant.kv_cache).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import apply_method
from repro.configs.paper_models import opt_tiny
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.optim.adamw import AdamWConfig
from repro.quant import QConfig
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.train.step import TrainTask, init_train_state, make_train_step

VOCAB, SEQ = 64, 32
TRAIN_STEPS = 400
METHODS = ("vanilla", "clipped_softmax", "gated_attention")
# thresholds (measured: clean agreement 1.0 for every method x backend;
# outlier-vanilla 0.0 at M=300 x 2 channels — margins are wide on purpose)
CLEAN_FLOOR = 0.9
OUTLIER_CEIL = 0.6
QC = QConfig()


def _cfg(method, backend="gather"):
    cfg = opt_tiny(vocab=VOCAB, seq_len=SEQ)
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, n_heads=2,
                              n_kv_heads=2, d_head=32, d_ff=256,
                              paged_backend=backend)
    if method == "clipped_softmax":
        return apply_method(cfg, method, alpha=4.0)
    return apply_method(cfg, method)


def _train(method):
    cfg = _cfg(method)
    task = TrainTask(cfg=cfg, optimizer=AdamWConfig(lr=1e-3))
    data = SyntheticLM(SyntheticLMConfig(vocab_size=VOCAB, seq_len=SEQ,
                                         batch_size=32, seed=0, branching=8))
    state = init_train_state(jax.random.PRNGKey(0), task)
    step_fn = jax.jit(make_train_step(task), donate_argnums=(0,))
    for i in range(TRAIN_STEPS):
        batch = jax.tree_util.tree_map(jnp.asarray, data.batch(i))
        state, _ = step_fn(state, batch)
    return state.params


def _inject_outliers(params, channels=(3, 11), m=300.0):
    """Function-preserving channel amplification (see module docstring)."""
    broken = jax.tree_util.tree_map(jnp.asarray, params)
    for layer in broken["layers"]:
        blk = layer["b0"]
        for c in channels:
            blk["mlp"]["up"]["w"] = blk["mlp"]["up"]["w"].at[:, c].mul(m)
            blk["mlp"]["up"]["b"] = blk["mlp"]["up"]["b"].at[c].mul(m)
            blk["mlp"]["down"]["w"] = blk["mlp"]["down"]["w"].at[c, :].mul(1.0 / m)
    return broken


@pytest.fixture(scope="module")
def trained():
    """method -> trained params (+ 'vanilla_outliers' variant)."""
    models = {m: _train(m) for m in METHODS}
    models["vanilla_outliers"] = _inject_outliers(models["vanilla"])
    return models


@pytest.fixture(scope="module")
def prompts():
    data = SyntheticLM(SyntheticLMConfig(vocab_size=VOCAB, seq_len=SEQ,
                                         batch_size=32, seed=0, branching=8))
    batch = data.batch(999)
    return [batch["tokens"][i][:12].astype(np.int32) for i in range(6)]


def _run_engine(params, cfg, prompts, qconfig=None, paged=True, **kw):
    b = ContinuousBatcher(params, cfg, batch_size=4, max_len=64, block_size=8,
                          paged=paged, qconfig=qconfig, **kw)
    for i, p in enumerate(prompts):
        b.submit(Request(uid=i, prompt=p, max_new_tokens=16))
    return {r.uid: np.asarray(r.output) for r in b.run()}


def _agreement(fp, q8):
    tot = match = 0
    for uid in fp:
        for x, y in zip(fp[uid], q8[uid]):
            tot += 1
            match += int(x == y)
    return match / max(tot, 1)


@pytest.fixture(scope="module")
def fp_outputs(trained, prompts):
    """Greedy fp-engine baselines, one dense engine per model (the fp
    reference is backend-independent: paged/dense engines are token-exact
    on the fp path, asserted in test_paged_cache/test_serving_engine)."""
    return {name: _run_engine(p, _cfg("vanilla" if name.startswith("vanilla")
                                      else name), prompts, paged=False)
            for name, p in trained.items()}


class TestTable2Agreement:
    """Outlier-free configs survive full INT8 serving; outliers break it."""

    @pytest.mark.parametrize("method", METHODS)
    @pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense"])
    def test_clean_models_agree_with_fp(self, trained, prompts, fp_outputs,
                                        method, paged):
        q8 = _run_engine(trained[method], _cfg(method), prompts,
                         qconfig=QC, paged=paged)
        ag = _agreement(fp_outputs[method], q8)
        assert ag >= CLEAN_FLOOR, (method, paged, ag)

    def test_outlier_vanilla_degrades_paged(self, trained, prompts, fp_outputs):
        """The headline contrast: same fp function as clean vanilla, but
        int8 serving collapses once per-tensor ranges carry outliers —
        while clipped/gated (which never grow them) stay at the floor."""
        q8 = _run_engine(trained["vanilla_outliers"], _cfg("vanilla"),
                         prompts, qconfig=QC, paged=True)
        bad = _agreement(fp_outputs["vanilla_outliers"], q8)
        assert bad <= OUTLIER_CEIL, bad
        for method in ("clipped_softmax", "gated_attention"):
            good = _agreement(
                fp_outputs[method],
                _run_engine(trained[method], _cfg(method), prompts,
                            qconfig=QC, paged=True))
            assert good >= CLEAN_FLOOR > bad, (method, good, bad)

    def test_kernel_backend_clean_and_outlier(self, trained, prompts,
                                              fp_outputs):
        """Same thresholds on the Pallas paged kernel (interpret mode):
        the per-block dequant epilogue must neither lose the clean models'
        agreement nor mask the outlier failure."""
        q8 = _run_engine(trained["clipped_softmax"],
                         _cfg("clipped_softmax", backend="kernel"),
                         prompts, qconfig=QC, paged=True)
        assert _agreement(fp_outputs["clipped_softmax"], q8) >= CLEAN_FLOOR
        q8_bad = _run_engine(trained["vanilla_outliers"],
                             _cfg("vanilla", backend="kernel"),
                             prompts, qconfig=QC, paged=True)
        assert _agreement(fp_outputs["vanilla_outliers"], q8_bad) <= OUTLIER_CEIL

    @pytest.mark.slow
    def test_kernel_backend_full_matrix(self, trained, prompts, fp_outputs):
        for method in METHODS:
            q8 = _run_engine(trained[method], _cfg(method, backend="kernel"),
                             prompts, qconfig=QC, paged=True)
            assert _agreement(fp_outputs[method], q8) >= CLEAN_FLOOR, method


class TestInt8KVInvariance:
    """Bitwise invariance of int8-KV serving (quantize-on-write pools) to
    scheduling accidents — the same oracles test_chunked_prefill runs for
    the fp engine. Random-init params suffice: equality is bitwise, not
    statistical. kv_int8 is forced on WITHOUT W8A8 first (isolating the
    pool), then the full int8 stack is checked for chunk invariance."""

    @pytest.fixture(scope="class")
    def setup(self):
        from repro.models import model_init
        cfg = _cfg("gated_attention")
        params = model_init(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(4, VOCAB, size=n).astype(np.int32)
                   for n in (11, 5, 17, 8)]
        return cfg, params, prompts

    def _run(self, cfg, params, prompts, qconfig=None, **kw):
        b = ContinuousBatcher(params, cfg, max_len=32, block_size=4,
                              paged=True, kv_int8=True, qconfig=qconfig, **kw)
        for i, p in enumerate(prompts):
            b.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        return {r.uid: np.asarray(r.output) for r in b.run()}

    def test_chunk_size_invariance(self, setup):
        cfg, params, prompts = setup
        ref = self._run(cfg, params, prompts, batch_size=4)
        for kw in (dict(token_budget=5), dict(token_budget=7),
                   dict(prefill_chunk=3)):
            out = self._run(cfg, params, prompts, batch_size=4, **kw)
            for uid in ref:
                np.testing.assert_array_equal(out[uid], ref[uid],
                                              err_msg=f"{kw} uid={uid}")

    def test_slot_assignment_invariance(self, setup):
        """Fewer slots than requests => different rows/physical blocks per
        request; outputs must not move (scale vectors ride the pool, not
        the slot)."""
        cfg, params, prompts = setup
        ref = self._run(cfg, params, prompts, batch_size=4)
        for b in (1, 2):
            out = self._run(cfg, params, prompts, batch_size=b)
            for uid in ref:
                np.testing.assert_array_equal(out[uid], ref[uid],
                                              err_msg=f"B={b} uid={uid}")

    def test_preemption_resume_invariance(self, setup):
        """A pool too small to hold every row forces preempt + recompute-
        resume; re-quantizing the recomputed prefix must reproduce the
        exact bits (one quantization per (value, position))."""
        cfg, params, prompts = setup
        roomy = self._run(cfg, params, prompts, batch_size=4)
        tight = self._run(cfg, params, prompts, batch_size=4, num_blocks=10)
        for uid in roomy:
            np.testing.assert_array_equal(tight[uid], roomy[uid],
                                          err_msg=f"uid={uid}")

    def test_full_int8_chunk_invariance(self, setup):
        """W8A8 + int8 KV together: calibration happens once at engine
        construction from fixed synthetic batches, so two engines over the
        same params are identical quantized programs and chunking still
        cannot move outputs."""
        cfg, params, prompts = setup
        ref = self._run(cfg, params, prompts, batch_size=4, qconfig=QC)
        out = self._run(cfg, params, prompts, batch_size=4, qconfig=QC,
                        token_budget=6)
        for uid in ref:
            np.testing.assert_array_equal(out[uid], ref[uid],
                                          err_msg=f"uid={uid}")
