"""Pallas kernels vs pure-jnp oracles: shape/dtype/config sweeps
(interpret mode on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fake_quant_op, linear_w8a8, mha_flash, rglru_op
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul, quantize_weights_int8
from repro.kernels.ref import (
    attention_ref, fake_quant_ref, int8_matmul_ref, rglru_ref,
)

KEY = jax.random.PRNGKey(0)

pytestmark = pytest.mark.slow  # Pallas interpret-mode kernel sweeps


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [(2, 128, 128, 64), (3, 96, 160, 32),
                                       (1, 33, 70, 16)])
    @pytest.mark.parametrize("variant", [
        dict(), dict(gamma=-0.03), dict(gamma=-0.01, zeta=1.03),
        dict(causal=False), dict(window=40),
        dict(softcap=30.0, gamma=-0.02), dict(q_offset=5)])
    def test_vs_oracle(self, shape, variant):
        bh, tq, tk, dh = shape
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (bh, tq, dh))
        k = jax.random.normal(ks[1], (bh, tk, dh))
        v = jax.random.normal(ks[2], (bh, tk, dh))
        o = flash_attention(q, k, v, None, block_q=64, block_kv=64, **variant)
        r = attention_ref(q, k, v, None, **variant)
        np.testing.assert_allclose(o, r, atol=3e-5)

    @pytest.mark.parametrize("gamma", [0.0, -0.05])
    def test_gated(self, gamma):
        bh, t, dh = 2, 64, 32
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (bh, t, dh))
        k = jax.random.normal(ks[1], (bh, t, dh))
        v = jax.random.normal(ks[2], (bh, t, dh))
        g = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, t)))
        o = flash_attention(q, k, v, g, gamma=gamma, block_q=32, block_kv=32)
        r = attention_ref(q, k, v, g, gamma=gamma)
        np.testing.assert_allclose(o, r, atol=3e-5)

    def test_bf16(self):
        q = jax.random.normal(KEY, (2, 64, 64), jnp.bfloat16)
        o = flash_attention(q, q, q, None, gamma=-0.02)
        r = attention_ref(q, q, q, None, gamma=-0.02)
        assert o.dtype == jnp.bfloat16
        np.testing.assert_allclose(o.astype(jnp.float32),
                                   r.astype(jnp.float32), atol=2e-2)

    def test_gqa_adapter_vs_core(self):
        from repro.core.attention import AttentionConfig, dense_attention
        from repro.core.softmax import ClippedSoftmaxConfig
        B, T, H, HKV, D = 2, 64, 8, 4, 32
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (B, T, H, D))
        k = jax.random.normal(ks[1], (B, T, HKV, D))
        v = jax.random.normal(ks[2], (B, T, HKV, D))
        gate = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H)))
        cfg = AttentionConfig(n_heads=H, n_kv_heads=HKV, d_head=D,
                              softmax=ClippedSoftmaxConfig(gamma=-0.03))
        o = mha_flash(q, k, v, gate, gamma=-0.03, block_q=32, block_kv=32)
        r = dense_attention(q, k, v, cfg, gate_pi=gate)
        np.testing.assert_allclose(o, r, atol=3e-5)


class TestInt8Matmul:
    @pytest.mark.parametrize("shape", [(128, 128, 128), (100, 70, 36),
                                       (256, 512, 384), (64, 1000, 200)])
    def test_vs_oracle(self, shape):
        m, k, n = shape
        x = jax.random.normal(KEY, (m, k)) * 2
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
        wq, ws = quantize_weights_int8(w)
        o = int8_matmul(x, wq, ws, block_m=64, block_n=64, block_k=64)
        r = int8_matmul_ref(x, wq, ws)
        np.testing.assert_allclose(o, r, atol=1e-3, rtol=1e-4)

    def test_quality_vs_float(self):
        """W8A8 of outlier-free activations is within ~2%% of fp matmul —
        the regime the paper's method creates."""
        x = jax.random.normal(KEY, (128, 256))
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.05
        wq, ws = quantize_weights_int8(w)
        o = linear_w8a8(x, wq, ws)
        f = x @ w
        rel = float(jnp.mean(jnp.abs(o - f)) / jnp.mean(jnp.abs(f)))
        assert rel < 0.03

    def test_outliers_destroy_w8a8(self):
        """With a BERT-like outlier the per-tensor range collapses — the
        failure mode the paper fixes at the architecture level."""
        x = jax.random.normal(KEY, (128, 256))
        x_out = x.at[0, 0].set(500.0)
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.05
        wq, ws = quantize_weights_int8(w)
        f = x_out @ w
        o = linear_w8a8(x_out, wq, ws)
        rel = float(jnp.mean(jnp.abs(o - f)) / jnp.mean(jnp.abs(f)))
        assert rel > 0.2   # catastrophic vs the 0.03 above


class TestFakeQuantKernel:
    @pytest.mark.parametrize("n", [1000, 4096, 777])
    @pytest.mark.parametrize("bits", [4, 8])
    def test_vs_oracle(self, n, bits):
        x = jax.random.normal(KEY, (n,)) * 3
        s, z = 0.05, 2.0 ** (bits - 1)
        np.testing.assert_allclose(
            fake_quant_op(x, s, z, bits), fake_quant_ref(x, s, z, bits),
            atol=1e-6)


class TestRGLRUKernel:
    @pytest.mark.parametrize("shape", [(2, 37, 24), (1, 128, 512), (3, 8, 700)])
    def test_vs_oracle(self, shape):
        b, t, d = shape
        a = jax.nn.sigmoid(jax.random.normal(KEY, shape))
        bb = jax.random.normal(jax.random.PRNGKey(1), shape)
        h, hl = rglru_op(a, bb)
        hr, hlr = rglru_ref(a, bb)
        np.testing.assert_allclose(h, hr, atol=1e-5)
        np.testing.assert_allclose(hl, hlr, atol=1e-5)

    def test_state_carry(self):
        a = jax.nn.sigmoid(jax.random.normal(KEY, (2, 16, 8)))
        b = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
        h_full, _ = rglru_ref(a, b)
        h1, hl1 = rglru_op(a[:, :9], b[:, :9])
        h2, _ = rglru_op(a[:, 9:], b[:, 9:], h0=hl1)
        np.testing.assert_allclose(
            jnp.concatenate([h1, h2], axis=1), h_full, atol=1e-5)
