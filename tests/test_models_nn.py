"""NN substrate: MoE dispatch, xLSTM chunkwise, RG-LRU scan, conv state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.nn.layers import conv1d_apply, conv1d_init
from repro.nn.moe import MoEConfig, moe_apply, moe_init
from repro.nn.recurrent import (
    RGLRUConfig, griffin_block_apply, griffin_block_init, griffin_init_state,
    rglru_scan, rglru_step, rglru_init,
)
from repro.nn.xlstm import (
    XLSTMConfig, mlstm_block_apply, mlstm_block_init, mlstm_chunkwise,
    mlstm_recurrent_ref, slstm_block_apply, slstm_block_init, xlstm_init_state,
)

KEY = jax.random.PRNGKey(0)


class TestMoE:
    def test_dispatch_matches_dense_with_slack_capacity(self):
        d = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=8.0,
                      group_size=64, exec_mode="dense")
        s = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=8.0,
                      group_size=64, exec_mode="dispatch")
        p = moe_init(KEY, 32, d)
        x = jax.random.normal(KEY, (2, 50, 32))
        yd, _ = moe_apply(p, x, d)
        ys, _ = moe_apply(p, x, s)
        np.testing.assert_allclose(yd, ys, atol=1e-4)

    def test_tight_capacity_finite(self):
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=0.25,
                        group_size=64, exec_mode="dispatch")
        p = moe_init(KEY, 32, cfg)
        y, _ = moe_apply(p, jax.random.normal(KEY, (2, 64, 32)), cfg)
        assert not bool(jnp.any(jnp.isnan(y)))

    def test_shared_experts(self):
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, n_shared_experts=2,
                        shared_d_ff=24, capacity_factor=4.0, group_size=32,
                        exec_mode="dispatch")
        p = moe_init(KEY, 32, cfg)
        y, aux = moe_apply(p, jax.random.normal(KEY, (1, 32, 32)), cfg)
        assert y.shape == (1, 32, 32)
        assert "load_balance" in aux and float(aux["load_balance"]) > 0

    def test_load_balance_loss_minimal_when_uniform(self):
        """LB loss lower-bounded by 1 (Switch); uniform routing hits it."""
        cfg = MoEConfig(n_experts=4, top_k=1, d_ff=8, exec_mode="dense")
        p = moe_init(KEY, 16, cfg)
        # uniform router
        p["router"]["w"] = jnp.zeros_like(p["router"]["w"])
        _, aux = moe_apply(p, jax.random.normal(KEY, (1, 256, 16)), cfg)
        assert float(aux["load_balance"]) == pytest.approx(1.0, abs=0.15)

    def test_inactive_rows_do_not_claim_capacity(self):
        """Serving regression: a dead decode-slot row's tokens must not
        displace a live row's tokens from expert capacity buffers.
        top_k == n_experts makes claims/expert == live-token count exactly,
        so with cap = one row's tokens the live row fits iff the dead row
        is masked — its output then equals the capacity-free dense oracle,
        while an unmasked dead row forces drops and changes it."""
        import dataclasses
        cfg = MoEConfig(n_experts=4, top_k=4, d_ff=32, capacity_factor=0.5,
                        group_size=4096, exec_mode="dispatch")
        p = moe_init(KEY, 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
        dense = dataclasses.replace(cfg, exec_mode="dense")
        y_masked, _ = moe_apply(p, x, cfg, active=jnp.asarray([True, False]))
        y_dense, _ = moe_apply(p, x, dense)
        np.testing.assert_allclose(np.asarray(y_masked[0]),
                                   np.asarray(y_dense[0]), atol=1e-5)
        # sanity: capacity IS contended — with the second row live the
        # first row's claims overflow and its output moves
        y_both, _ = moe_apply(p, x, cfg)
        assert float(jnp.max(jnp.abs(y_both[0] - y_dense[0]))) > 1e-4

    def test_mesh_probe_fallback_still_triggers(self, monkeypatch):
        """The mesh probes in _moe_dispatch narrowed from `except
        Exception` to (AttributeError, KeyError, TypeError): dispatch must
        still fall back to unsharded execution when the abstract-mesh API
        is missing (older jax), and must NOT swallow unrelated errors."""
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=4.0,
                        group_size=32, exec_mode="dispatch")
        p = moe_init(KEY, 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 40, 16))
        y_base, _ = moe_apply(p, x, cfg)

        def no_api():
            raise AttributeError("module 'jax.sharding' has no attribute "
                                 "'get_abstract_mesh'")

        monkeypatch.setattr(jax.sharding, "get_abstract_mesh", no_api,
                            raising=False)
        y_fb, _ = moe_apply(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y_fb), np.asarray(y_base),
                                   atol=0)

        def broken():
            raise RuntimeError("not a mesh-probe failure")

        monkeypatch.setattr(jax.sharding, "get_abstract_mesh", broken,
                            raising=False)
        with pytest.raises(RuntimeError, match="not a mesh-probe"):
            moe_apply(p, x, cfg)

    def test_mesh_probe_loop_keeps_token_count(self, monkeypatch):
        """Regression: the probe's axis loop used to shadow the token
        count `n` (`for n in am.axis_names`), corrupting the `y[:n]`
        unpad slice whenever a mesh was active AND the group padded."""
        import types
        fake = types.SimpleNamespace(axis_names=("a", "b"),
                                     shape={"a": 1, "b": 1})
        monkeypatch.setattr(jax.sharding, "get_abstract_mesh",
                            lambda: fake, raising=False)
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=4.0,
                        group_size=32, exec_mode="dispatch")
        p = moe_init(KEY, 16, cfg)
        # 40 tokens, group 32 -> pad 24: the unpad slice must return 40
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 40, 16))
        # real (trivial) mesh so the sharding constraints the probe's
        # result triggers are legal on this single CPU device
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        with jax.sharding.Mesh(devs, ("a", "b")):
            y, _ = moe_apply(p, x, cfg)
        assert y.shape == (1, 40, 16)

    @given(seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_grad_flows(self, seed):
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=8, capacity_factor=2.0,
                        group_size=32, exec_mode="dispatch")
        p = moe_init(jax.random.PRNGKey(seed), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32, 16))
        g = jax.grad(lambda pp: moe_apply(pp, x, cfg)[0].sum())(p)
        gn = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
        assert np.isfinite(gn) and gn > 0


class TestXLSTM:
    def test_chunkwise_matches_recurrent(self):
        B, T, H, D = 2, 37, 3, 8
        ks = jax.random.split(KEY, 5)
        q, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
        logi = jax.random.normal(ks[3], (B, T, H))
        logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, T, H)) + 2)
        h_ref, s_ref = mlstm_recurrent_ref(q, k, v, logi, logf)
        h_ck, s_ck = mlstm_chunkwise(q, k, v, logi, logf, chunk=16)
        np.testing.assert_allclose(h_ref, h_ck, atol=1e-4)
        for a, b in zip(s_ref, s_ck):
            np.testing.assert_allclose(a, b, atol=1e-3)

    def test_state_continuation(self):
        B, T, H, D = 1, 24, 2, 4
        ks = jax.random.split(KEY, 5)
        q, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
        logi = jax.random.normal(ks[3], (B, T, H))
        logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, T, H)))
        h_full, _ = mlstm_chunkwise(q, k, v, logi, logf, chunk=8)
        h1, s1 = mlstm_chunkwise(q[:, :10], k[:, :10], v[:, :10],
                                 logi[:, :10], logf[:, :10], chunk=8)
        h2, _ = mlstm_chunkwise(q[:, 10:], k[:, 10:], v[:, 10:],
                                logi[:, 10:], logf[:, 10:], chunk=8, state=s1)
        np.testing.assert_allclose(
            jnp.concatenate([h1, h2], axis=1), h_full, atol=1e-4)

    @pytest.mark.slow
    def test_mlstm_block_decode_matches_full(self):
        cfg = XLSTMConfig(d_model=32, n_heads=4, chunk_size=8)
        p = mlstm_block_init(KEY, cfg)
        x = jax.random.normal(KEY, (2, 16, 32))
        full, _ = mlstm_block_apply(p, x, cfg, state=xlstm_init_state(2, "mlstm", cfg))
        st_ = xlstm_init_state(2, "mlstm", cfg)
        outs = []
        for t in range(16):
            o, st_ = mlstm_block_apply(p, x[:, t:t + 1], cfg, state=st_)
            outs.append(o)
        np.testing.assert_allclose(full, jnp.concatenate(outs, axis=1), atol=2e-3)

    def test_slstm_block(self):
        cfg = XLSTMConfig(d_model=32, n_heads=4)
        p = slstm_block_init(KEY, cfg)
        y, st_ = slstm_block_apply(p, jax.random.normal(KEY, (2, 12, 32)), cfg)
        assert y.shape == (2, 12, 32) and not bool(jnp.any(jnp.isnan(y)))


class TestGriffin:
    def test_assoc_scan_matches_step(self):
        cfg = RGLRUConfig(width=16)
        p = rglru_init(KEY, cfg)
        x = jax.random.normal(KEY, (2, 20, 16))
        y_scan, h_last = rglru_scan(p, x)
        h = jnp.zeros((2, 16))
        outs = []
        for t in range(20):
            o, h = rglru_step(p, x[:, t], h)
            outs.append(o[:, None])
        np.testing.assert_allclose(y_scan, jnp.concatenate(outs, axis=1), atol=1e-5)
        np.testing.assert_allclose(h_last, h, atol=1e-5)

    def test_block_decode_consistency(self):
        cfg = RGLRUConfig(width=32)
        p = griffin_block_init(KEY, 32, cfg)
        x = jax.random.normal(KEY, (2, 12, 32))
        full, _ = griffin_block_apply(p, x, cfg, state=griffin_init_state(2, cfg))
        st_ = griffin_init_state(2, cfg)
        outs = []
        for t in range(12):
            o, st_ = griffin_block_apply(p, x[:, t:t + 1], cfg, state=st_)
            outs.append(o)
        np.testing.assert_allclose(full, jnp.concatenate(outs, axis=1), atol=1e-4)

    def test_rglru_decay_range_at_init(self):
        cfg = RGLRUConfig(width=64)
        p = rglru_init(KEY, cfg)
        a_max = jnp.exp(-8.0 * jax.nn.softplus(p["lambda"]) * 0.0)
        a_mid = jnp.exp(-8.0 * jax.nn.softplus(p["lambda"]) * 1.0)
        assert float(a_max.min()) == 1.0
        assert 0.85 <= float(a_mid.min()) and float(a_mid.max()) <= 0.9995


class TestConv:
    def test_causal_state_equivalence(self):
        p = conv1d_init(KEY, 8, 4)
        x = jax.random.normal(KEY, (2, 10, 8))
        y_full, _ = conv1d_apply(p, x)
        state = jnp.zeros((2, 3, 8))
        outs = []
        for t in range(10):
            o, state = conv1d_apply(p, x[:, t:t + 1], state)
            outs.append(o)
        np.testing.assert_allclose(y_full, jnp.concatenate(outs, axis=1), atol=1e-5)
