"""Paged KV cache: paged-vs-dense decode equivalence (bitwise), block
free-list reclamation, admission beyond the dense per-slot budget, the >=2x
short-request capacity win at equal pool memory, and recompute preemption
when the pool over-commits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.models.transformer import (
    ModelConfig,
    init_cache,
    init_paged_cache,
    model_apply,
)
from repro.serving import (
    BlockAllocator,
    ContinuousBatcher,
    GenerateConfig,
    Request,
    generate,
)

KEY = jax.random.PRNGKey(0)


def _setup(vocab=64):
    cfg = dataclasses.replace(opt_tiny(vocab=vocab, seq_len=32), max_seq_len=64)
    return cfg, model_init(KEY, cfg)


def _tiny(**kw):
    """Smallest config that still exercises attention + mlp, for tests whose
    cost is dominated by the number of prefills rather than realism."""
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                d_ff=64, vocab_size=64, pos="rope", max_seq_len=1024,
                scan_layers=False, remat=False, mlp_kind="swiglu",
                norm="rmsnorm")
    base.update(kw)
    return ModelConfig(**base)


def _refs(params, cfg, prompts, max_new):
    return [np.asarray(generate(params, cfg, jnp.asarray(p)[None, :],
                                GenerateConfig(max_new_tokens=m))[0, len(p):])
            for p, m in zip(prompts, max_new)]


def _ref_free(params, cfg, prompt, max_new):
    """Cache-free greedy oracle: grow the sequence one token at a time with
    full forward passes. Ground truth even where ``generate`` cannot go
    (a local_attn prompt longer than the window wraps its one-shot ring
    prefill)."""
    seq = list(map(int, prompt))
    out = []
    for _ in range(max_new):
        logits, _ = model_apply(params, cfg,
                                {"tokens": jnp.asarray([seq], jnp.int32)})
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        seq.append(tok)
    return np.asarray(out, np.int32)


def _run_batcher(params, cfg, prompts, max_new, **kw):
    b = ContinuousBatcher(params, cfg, **kw)
    for u, (p, m) in enumerate(zip(prompts, max_new)):
        b.submit(Request(uid=u, prompt=p, max_new_tokens=m))
    out = {r.uid: r.output for r in b.run()}
    return b, out


class TestPagedModelApply:
    def test_prefill_and_decode_bitwise_match_dense(self):
        """Same tokens through a scrambled-block-table paged cache and a
        dense cache produce bitwise identical logits (prefill + one fused
        per-row decode step with an active mask)."""
        cfg, params = _setup()
        prompt = jnp.arange(4, 12, dtype=jnp.int32)[None, :]
        dl, daux = model_apply(params, cfg, {"tokens": prompt},
                               cache=init_cache(cfg, 1, 32), pos=0)
        pcache = init_paged_cache(cfg, 1, 32, num_blocks=6, block_size=8)
        table = jnp.asarray([[2, 0, 3, -1]], jnp.int32)   # scrambled physical

        def set_table(path, leaf):
            if path and path[-1] == jax.tree_util.DictKey("block_table"):
                return jnp.broadcast_to(table, leaf.shape[:-2] + table.shape)
            return leaf

        pcache = jax.tree_util.tree_map_with_path(set_table, pcache)
        pl, paux = model_apply(params, cfg, {"tokens": prompt},
                               cache=pcache, pos=0)
        np.testing.assert_array_equal(np.asarray(dl), np.asarray(pl))

        tok = jnp.argmax(dl[:, -1:], -1).astype(jnp.int32)
        posv, act = jnp.asarray([8], jnp.int32), jnp.asarray([True])
        dl2, _ = model_apply(params, cfg, {"tokens": tok},
                             cache=daux["cache"], pos=posv, active=act)
        pl2, _ = model_apply(params, cfg, {"tokens": tok},
                             cache=paux["cache"], pos=posv, active=act)
        np.testing.assert_array_equal(np.asarray(dl2), np.asarray(pl2))

    def test_inactive_rows_do_not_write_pool(self):
        """active=False rows must not touch the shared pool — the paged form
        of the masked-scatter contract (a clobbered pool block would corrupt
        ANOTHER request, not just the dead row)."""
        cfg, params = _setup()
        cache = init_paged_cache(cfg, 2, 32, num_blocks=8, block_size=8)
        table = jnp.asarray([[0, 1, -1, -1], [2, 3, -1, -1]], jnp.int32)

        def set_table(path, leaf):
            if path and path[-1] == jax.tree_util.DictKey("block_table"):
                return table
            return leaf

        cache = jax.tree_util.tree_map_with_path(set_table, cache)
        toks = jnp.asarray([[5], [9]], jnp.int32)
        _, aux = model_apply(params, cfg, {"tokens": toks}, cache=cache,
                             pos=jnp.asarray([3, 7], jnp.int32),
                             active=jnp.asarray([True, False]))
        for g, gn in zip(init_paged_cache(cfg, 2, 32, 8, 8)["layers"],
                         aux["cache"]["layers"]):
            for name in g:
                for kv in ("k", "v"):
                    new = np.asarray(gn[name][kv])
                    # row 1 owns blocks 2/3; its write (pos 7 -> block 0 of
                    # its table = pool block 2) must have been dropped
                    assert not new[2:4].any()
                    # row 0 wrote pos 3 -> its block 0 = pool block 0
                    assert new[0].any()


class TestPagedVsDenseBatcher:
    @pytest.mark.slow
    def test_same_tokens_for_same_prompts(self):
        """Dense and paged batchers emit identical greedy tokens, both equal
        to a dedicated sequential generate per request (exact match)."""
        cfg, params = _setup()
        rng = np.random.default_rng(3)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (5, 3, 8, 4, 6)]
        max_new = [6, 8, 5, 7, 6]
        refs = _refs(params, cfg, prompts, max_new)
        _, dense = _run_batcher(params, cfg, prompts, max_new,
                                batch_size=2, max_len=32)
        _, paged = _run_batcher(params, cfg, prompts, max_new,
                                batch_size=2, max_len=32,
                                paged=True, block_size=8)
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(dense[u], ref, err_msg=f"uid={u}")
            np.testing.assert_array_equal(paged[u], ref, err_msg=f"uid={u}")

    @pytest.mark.slow
    def test_clipped_softmax_paged_matches_dense(self):
        """gamma = -alpha/T resolves from the KV axis length, so paged and
        dense batchers must present identical KV lengths (init_paged_cache
        enforces block_size | max_len) — outputs stay exactly equal under
        the paper's clipped softmax, not just vanilla."""
        from repro.configs import apply_method
        cfg, _ = _setup()
        cfg = apply_method(cfg, "clipped_softmax", alpha=4.0)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(13)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (5, 7, 4)]
        max_new = [6, 5, 7]
        _, dense = _run_batcher(params, cfg, prompts, max_new,
                                batch_size=2, max_len=32)
        _, paged = _run_batcher(params, cfg, prompts, max_new,
                                batch_size=2, max_len=32,
                                paged=True, block_size=8)
        for u in range(len(prompts)):
            np.testing.assert_array_equal(paged[u], dense[u], err_msg=f"uid={u}")

    def test_block_size_must_divide_max_len(self):
        cfg = _tiny()
        with pytest.raises(ValueError, match="multiple of block_size"):
            init_paged_cache(cfg, 1, 20, num_blocks=4, block_size=8)

    def test_mixed_pattern_ring_plus_paged(self):
        """Patterns mixing global attn (paged pool) with local_attn (dense
        ring) must admit and decode correctly: admission prefills against a
        batch-1 view (fresh ring row + live pools), not the batch-B cache.
        Two sequential occupants of the same slot also guard against stale
        ring pos_ids leaking into the second request's prefill."""
        cfg = _tiny(pattern=("attn", "local_attn"), window=16, max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (6, 4, 8)]
        max_new = [5, 6, 4]
        refs = _refs(params, cfg, prompts, max_new)
        _, out = _run_batcher(params, cfg, prompts, max_new,
                              batch_size=1, max_len=32,
                              paged=True, block_size=8)
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")

    @pytest.mark.slow
    def test_scanned_layers_paged(self):
        """Scanned caches stack the pools (G, num_blocks, bs, H, D) and the
        tables (G, B, W); the batcher must thread both through lax.scan."""
        cfg = _tiny(scan_layers=True, max_seq_len=64)
        params = model_init(KEY, cfg)
        p = np.arange(4, 9, dtype=np.int32)
        ref = _refs(params, cfg, [p], [4])[0]
        _, out = _run_batcher(params, cfg, [p], [4], batch_size=2, max_len=32,
                              paged=True, block_size=8)
        np.testing.assert_array_equal(out[0], ref)


class TestBlockAccounting:
    def test_free_list_reclaimed_after_run(self):
        """Every block returns to the free list after retirement — no leak
        across repeated run() generations on the same batcher."""
        cfg = _tiny(max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(0)
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32,
                              paged=True, block_size=8, num_blocks=8)
        for generation in range(2):
            for u in range(4):
                b.submit(Request(uid=u, prompt=rng.integers(
                    4, 60, size=5).astype(np.int32), max_new_tokens=5))
            done = b.run()
            assert len(done) == 4 * (generation + 1)
            assert b.allocator.available == b.num_blocks
            assert (b.tables == -1).all()

    def test_allocator_all_or_nothing(self):
        a = BlockAllocator(4)
        assert a.alloc(5) is None and a.available == 4
        got = a.alloc(3)
        assert len(got) == 3 and a.available == 1
        assert a.alloc(2) is None and a.available == 1
        a.free(got)
        assert a.available == 4
        assert sorted(a.alloc(4)) == [0, 1, 2, 3]

    def test_long_prompt_fits_blocks_but_not_dense_slot(self):
        """A 40-token prompt overflows a dense max_len=32 slot but is
        admitted by a paged pool of the SAME total memory (2 slots * 32 =
        4 blocks * 16) because max_len is only a logical cap there."""
        cfg = _tiny(max_seq_len=128)
        params = model_init(KEY, cfg)
        prompt = np.arange(4, 44, dtype=np.int32)   # 40 tokens
        dense = ContinuousBatcher(params, cfg, batch_size=2, max_len=32)
        with pytest.raises(ValueError, match="do not fit"):
            dense.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        ref = _refs(params, cfg, [prompt], [6])[0]
        _, out = _run_batcher(params, cfg, [prompt], [6],
                              batch_size=2, max_len=64,
                              paged=True, block_size=16, num_blocks=4)
        np.testing.assert_array_equal(out[0], ref)


class TestCapacity:
    @pytest.mark.slow
    def test_2x_short_request_admission_at_equal_memory(self):
        """Acceptance: with block_size=16, a pool worth N=2 dense slots of
        max_len=512 admits >= 2x more concurrent <=64-token requests under
        the paged allocator (here: 8x)."""
        cfg = _tiny()
        params = model_init(KEY, cfg)
        n_dense_slots, max_len, block = 2, 512, 16
        num_blocks = n_dense_slots * max_len // block            # 64
        rng = np.random.default_rng(1)
        prompts = [rng.integers(4, 60, size=48).astype(np.int32)
                   for _ in range(16)]
        max_new = [16] * 16                                      # <= 64 total

        # token_budget must cover one 48-token chunk per admitted row for
        # every row to advance on the FIRST tick (the quantity this test
        # measures is pool capacity, not budget throttling)
        dense = ContinuousBatcher(params, cfg, batch_size=n_dense_slots,
                                  max_len=max_len, token_budget=1024)
        paged = ContinuousBatcher(params, cfg, batch_size=16, max_len=max_len,
                                  paged=True, block_size=block,
                                  num_blocks=num_blocks, token_budget=1024)
        for b in (dense, paged):
            for u, p in enumerate(prompts):
                b.submit(Request(uid=u, prompt=p, max_new_tokens=max_new[u]))
        dense_concurrent = dense.step()
        paged_concurrent = paged.step()
        assert dense_concurrent == n_dense_slots
        assert paged_concurrent >= 2 * dense_concurrent
        assert paged_concurrent == 16     # ceil(49/16)=4 blocks/req, 64/4=16


class TestPreemption:
    @pytest.mark.slow
    def test_pool_exhaustion_preempts_and_resumes_exactly(self):
        """Two growing requests over-commit a 6-block pool: the youngest is
        preempted (blocks freed, recompute-resume from the queue front) and
        both still produce exactly the sequential-generate tokens, with the
        pool fully reclaimed afterwards."""
        cfg, params = _setup()
        rng = np.random.default_rng(7)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(2)]
        max_new = [12, 12]   # grows to 20 tokens = 5 blocks each; pool has 6
        refs = _refs(params, cfg, prompts, max_new)
        b, out = _run_batcher(params, cfg, prompts, max_new,
                              batch_size=2, max_len=32,
                              paged=True, block_size=4, num_blocks=6)
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")
        assert b.allocator.available == b.num_blocks
        assert (b.tables == -1).all()

    @pytest.mark.slow
    def test_preempt_with_ring_inside_window_resumes_exactly(self):
        """Preempting a mixed attn+local_attn row whose resume prefill fits
        the window must stay exact — the resume path re-prefills the ring
        from scratch like any admission."""
        cfg = _tiny(pattern=("attn", "local_attn"), window=16, max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(9)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(2)]
        max_new = [12, 12]   # stalls at pos 12 <= window 16 -> preemptable
        refs = _refs(params, cfg, prompts, max_new)
        b, out = _run_batcher(params, cfg, prompts, max_new,
                              batch_size=2, max_len=32,
                              paged=True, block_size=4, num_blocks=6)
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")
        assert b.allocator.available == b.num_blocks

    def test_preempt_past_ring_window_resumes_exactly(self):
        """A stalled row past the local_attn window IS preemptable now:
        recompute-resume re-enters the chunked prefill path (chunks capped
        at the window), which the seed's one-shot ring prefill had to
        refuse with a RuntimeError. Both requests still produce exactly
        the cache-free oracle's tokens and the pool fully reclaims."""
        cfg = _tiny(pattern=("attn", "local_attn"), window=8, max_seq_len=64)
        params = model_init(KEY, cfg)
        rng = np.random.default_rng(9)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(2)]
        refs = [_ref_free(params, cfg, p, 12) for p in prompts]
        b, out = _run_batcher(params, cfg, prompts, [12, 12],
                              batch_size=2, max_len=32,
                              paged=True, block_size=4, num_blocks=6)
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")
        assert b.allocator.available == b.num_blocks
        assert (b.tables == -1).all()

    def test_single_request_larger_than_pool_raises(self):
        cfg = _tiny(max_seq_len=64)
        params = model_init(KEY, cfg)
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=64,
                              paged=True, block_size=4, num_blocks=3)
        b.submit(Request(uid=0, prompt=np.arange(4, 12, dtype=np.int32),
                         max_new_tokens=20))
        with pytest.raises((RuntimeError, ValueError), match="pool"):
            b.run()
