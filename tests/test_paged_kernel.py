"""Pallas paged-attention decode kernel vs the XLA gather oracle
(``paged_attention(..., backend="gather")``), interpret mode on CPU.

The sweep covers the full attention contract the gather path owns: GQA
ratios, causal + local-window masks over logical positions from ragged
per-row ``q_offset`` vectors, unallocated (-1) table entries, partially
filled tail blocks, logit soft-capping, vanilla vs clipped softmax
(gamma/zeta, including alpha-resolved gamma) vs gated attention, dtypes,
and the static ``live_width`` prefix slicing the scheduler uses.

Accumulation order differs (blockwise streaming vs materialized einsum),
so agreement is to f32 round-off (atol 2e-5; bf16 2e-2), not bitwise —
see kernels/paged_attention.py's module docstring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionConfig, paged_attention
from repro.core.softmax import ClippedSoftmaxConfig
from repro.kernels.paged_attention import paged_mha

KEY = jax.random.PRNGKey(0)


def _case(b=3, w=4, bs=8, hq=4, hkv=2, dh=16, tq=1, dtype=jnp.float32,
          seed=0, ragged=True):
    """Random pool + scrambled prefix-dense tables + ragged positions.

    Rows sit at unrelated positions; each owns exactly the blocks covering
    [0, pos + tq), so the last owned block is partially filled whenever
    pos + tq is not a block multiple."""
    nb = b * w + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, tq, hq, dh), dtype)
    k_pool = jax.random.normal(ks[1], (nb, bs, hkv, dh), dtype)
    v_pool = jax.random.normal(ks[2], (nb, bs, hkv, dh), dtype)
    rng = np.random.default_rng(seed)
    max_pos = w * bs - tq
    pos = rng.integers(0, max_pos + 1, size=b) if ragged \
        else np.full(b, max_pos // 2)
    table = np.full((b, w), -1, np.int32)
    perm = rng.permutation(nb)
    nxt = 0
    for i in range(b):
        need = -(-(int(pos[i]) + tq) // bs)        # ceil: partial tail block
        table[i, :need] = perm[nxt:nxt + need]
        nxt += need
    gate = jax.nn.sigmoid(jax.random.normal(ks[3], (b, tq, hq))).astype(dtype)
    return (q, k_pool, v_pool, jnp.asarray(table),
            jnp.asarray(pos, jnp.int32), gate)


def _check(q, k_pool, v_pool, table, pos, cfg, gate=None, live_width=None,
           atol=2e-5):
    ref = paged_attention(q, k_pool, v_pool, table, cfg, q_offset=pos,
                          gate_pi=gate, backend="gather",
                          live_width=live_width)
    out = paged_attention(q, k_pool, v_pool, table, cfg, q_offset=pos,
                          gate_pi=gate, backend="kernel", interpret=True,
                          live_width=live_width)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


SOFTMAXES = [
    ClippedSoftmaxConfig(),
    ClippedSoftmaxConfig(gamma=-0.03),
    ClippedSoftmaxConfig(gamma=-0.01, zeta=1.03),
    ClippedSoftmaxConfig(alpha=4.0),
]


class TestPagedKernelFast:
    """Small fixed cases per variant — fast tier (`-m "not slow"`)."""

    @pytest.mark.parametrize("sm", SOFTMAXES)
    def test_softmax_variants_ragged_positions(self, sm):
        q, kp, vp, tbl, pos, _ = _case()
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16, softmax=sm)
        _check(q, kp, vp, tbl, pos, cfg)

    def test_gated_clipped(self):
        q, kp, vp, tbl, pos, gate = _case()
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(gamma=-0.03))
        _check(q, kp, vp, tbl, pos, cfg, gate=gate)

    def test_local_window(self):
        q, kp, vp, tbl, pos, _ = _case(w=6)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16, window=11,
                              softmax=ClippedSoftmaxConfig(gamma=-0.02))
        _check(q, kp, vp, tbl, pos, cfg)

    def test_softcap(self):
        q, kp, vp, tbl, pos, _ = _case()
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              logit_softcap=30.0,
                              softmax=ClippedSoftmaxConfig(alpha=4.0))
        _check(q, kp, vp, tbl, pos, cfg)

    def test_live_width_slicing_exact(self):
        """Slicing the read to the allocated prefix must not change the
        result — including the alpha-resolved clip threshold, which is
        pinned to the LOGICAL length before slicing."""
        q, kp, vp, tbl, pos, _ = _case(w=8, seed=3)
        held = int(np.max(np.sum(np.asarray(tbl) >= 0, axis=1)))
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(alpha=4.0))
        full = paged_attention(q, kp, vp, tbl, cfg, q_offset=pos,
                               backend="gather")
        for backend in ("gather", "kernel"):
            sliced = paged_attention(q, kp, vp, tbl, cfg, q_offset=pos,
                                     backend=backend, interpret=True,
                                     live_width=held)
            np.testing.assert_allclose(np.asarray(sliced), np.asarray(full),
                                       atol=2e-5, err_msg=backend)

    def test_per_row_live_widths_exact(self):
        """Masking each row's gather read at its OWN block count (instead of
        the tick max) must be bitwise-neutral: allocation is prefix-dense,
        so the masked entries were -1 (already dead) — AND it must win when
        they are not: stale garbage ids beyond a row's count are hidden by
        the per-row mask where the bare -1 test would read them."""
        q, kp, vp, tbl, pos, _ = _case(w=8, seed=3)
        counts = np.sum(np.asarray(tbl) >= 0, axis=1)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(alpha=4.0))
        lws = jnp.asarray(counts, jnp.int32)
        # at a FIXED table width the per-row mask is bitwise-neutral (the
        # masked entries contributed exact zeros already) — both without
        # and combined with the static live_width slice
        for lw in (None, int(counts.max())):
            full = paged_attention(q, kp, vp, tbl, cfg, q_offset=pos,
                                   backend="gather", live_width=lw)
            per_row = paged_attention(q, kp, vp, tbl, cfg, q_offset=pos,
                                      backend="gather", live_width=lw,
                                      live_widths=lws)
            np.testing.assert_array_equal(np.asarray(per_row),
                                          np.asarray(full), err_msg=str(lw))
        # stale ids beyond each row's count: the per-row mask must hide
        # them. Discriminating case needs causal=False — under a causal
        # mask those positions are unreachable anyway, which is exactly why
        # masking them is bitwise-free in the serving path.
        cfg_nc = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                                 causal=False)
        full_nc = paged_attention(q, kp, vp, tbl, cfg_nc, q_offset=pos,
                                  backend="gather")
        stale = np.asarray(tbl).copy()
        for b in range(stale.shape[0]):
            stale[b, counts[b]:] = 0               # valid-looking garbage
        leaky = paged_attention(q, kp, vp, jnp.asarray(stale), cfg_nc,
                                q_offset=pos, backend="gather")
        assert not np.array_equal(np.asarray(leaky), np.asarray(full_nc))
        with_stale = paged_attention(q, kp, vp, jnp.asarray(stale), cfg_nc,
                                     q_offset=pos, backend="gather",
                                     live_widths=jnp.asarray(counts, jnp.int32))
        np.testing.assert_array_equal(np.asarray(with_stale),
                                      np.asarray(full_nc))

    def test_bf16(self):
        q, kp, vp, tbl, pos, gate = _case(dtype=jnp.bfloat16)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(gamma=-0.02))
        _check(q, kp, vp, tbl, pos, cfg, gate=gate, atol=2e-2)

    def test_unallocated_row_outputs_zero(self):
        """A row whose table is all -1 (never admitted) attends to nothing:
        both backends emit exact zeros for it."""
        q, kp, vp, tbl, pos, _ = _case()
        tbl = tbl.at[1].set(-1)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(gamma=-0.03))
        for backend in ("gather", "kernel"):
            out = paged_attention(q, kp, vp, tbl, cfg, q_offset=pos,
                                  backend=backend, interpret=True)
            assert not np.asarray(out[1]).any(), backend


class TestPagedKernelSweep:
    """Wider parametrized sweep — slow tier."""

    pytestmark = pytest.mark.slow

    @pytest.mark.parametrize("group", [1, 2, 4])
    @pytest.mark.parametrize("sm", SOFTMAXES)
    @pytest.mark.parametrize("window", [None, 13])
    def test_gqa_window_softmax(self, group, sm, window):
        hkv = 2
        q, kp, vp, tbl, pos, gate = _case(hq=group * hkv, hkv=hkv, w=5,
                                          seed=group)
        cfg = AttentionConfig(n_heads=group * hkv, n_kv_heads=hkv, d_head=16,
                              window=window, softmax=sm)
        _check(q, kp, vp, tbl, pos, cfg, gate=gate)

    @pytest.mark.parametrize("tq", [2, 5])
    def test_multi_token_query_block(self, tq):
        """Tq > 1 (speculative / chunked-prefill shapes): causal masking
        inside the query block over logical positions."""
        q, kp, vp, tbl, pos, gate = _case(tq=tq, w=5, seed=tq)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(alpha=4.0))
        _check(q, kp, vp, tbl, pos, cfg, gate=gate)

    @pytest.mark.parametrize("seed", range(4))
    def test_random_tables(self, seed):
        q, kp, vp, tbl, pos, _ = _case(b=4, w=7, bs=4, seed=10 + seed)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16,
                              softmax=ClippedSoftmaxConfig(gamma=-0.05))
        _check(q, kp, vp, tbl, pos, cfg)

    def test_scalar_offset(self):
        q, kp, vp, tbl, pos, _ = _case(b=2, ragged=False)
        cfg = AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16)
        _check(q, kp, vp, tbl, int(pos[0]), cfg)


class TestKernelEndToEnd:
    @pytest.mark.slow
    def test_batcher_tokens_identical_with_kernel_backend(self):
        """The whole serving stack over the Pallas read path (interpret
        mode) emits the same greedy tokens as the gather path / sequential
        generate — the kernel drops into the fused tick unchanged."""
        from repro.models import model_init
        from repro.models.transformer import ModelConfig
        from repro.serving import ContinuousBatcher, GenerateConfig, Request, generate

        base = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=4,
                           n_kv_heads=2, d_ff=64, vocab_size=64, pos="rope",
                           max_seq_len=1024, scan_layers=False, remat=False,
                           mlp_kind="swiglu", norm="rmsnorm",
                           softmax_cfg=ClippedSoftmaxConfig(alpha=4.0))
        params = model_init(KEY, base)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (6, 4)]
        refs = [np.asarray(generate(params, base, jnp.asarray(p)[None, :],
                                    GenerateConfig(max_new_tokens=5))[0, len(p):])
                for p in prompts]
        cfg = dataclasses.replace(base, paged_backend="kernel")
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32,
                              paged=True, block_size=8)
        for u, p in enumerate(prompts):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=5))
        out = {r.uid: r.output for r in b.run()}
        for u, ref in enumerate(refs):
            np.testing.assert_array_equal(out[u], ref, err_msg=f"uid={u}")
