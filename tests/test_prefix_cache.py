"""Prefix cache subsystem: refcounted allocator semantics, trie
match/insert/LRU-evict, bitwise equality of shared-prefix admission vs
cold admission (fp and int8-KV, incl. preempt-swap-resume of a row
holding shared blocks), cached-prefix TTFT of one tick with zero prefill
chunks for the shared span, LRU eviction never blocking admission, and
``Request(n=...)`` parallel sampling matching n independent requests
with the same seeds on dense/paged × fp/int8-KV engines — with the
refcount audit live (``debug_audit=True``) throughout."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import (
    AllocatorAuditError,
    BlockAllocator,
    ContinuousBatcher,
    GenerateConfig,
    PrefixCache,
    Request,
)

KEY = jax.random.PRNGKey(0)
BS = 8                                   # block size used across the file


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                              max_seq_len=64)
    return cfg, model_init(KEY, cfg)


def _engine(setup, **kw):
    cfg, params = setup
    base = dict(batch_size=4, max_len=64, token_budget=48, paged=True,
                block_size=BS, num_blocks=32, prefix_cache=True,
                debug_audit=True)
    base.update(kw)
    return ContinuousBatcher(params, cfg, **base)


def _prompt(n, lo=4):
    return (np.arange(n) % 50 + lo).astype(np.int32)


def _drain(b, max_ticks=500):
    ticks = 0
    while b.queue or any(s.req is not None for s in b.slots):
        b.step()
        ticks += 1
        assert ticks < max_ticks
    return ticks


# ---------------------------------------------------------------------------
class TestAllocatorRefcounts:
    def test_alloc_acquire_release_cycle(self):
        a = BlockAllocator(4)
        got = a.alloc(2)
        assert sorted(a.refcount(b) for b in got) == [1, 1]
        a.acquire(got)                   # second owner
        assert all(a.refcount(b) == 2 for b in got)
        a.release(got)                   # first owner lets go: still live
        assert a.available == 2
        assert all(a.refcount(b) == 1 for b in got)
        a.release(got)                   # last owner: back on the free list
        assert a.available == 4
        assert all(a.refcount(b) == 0 for b in got)

    def test_release_of_free_block_raises(self):
        a = BlockAllocator(2)
        got = a.alloc(1)
        a.release(got)
        with pytest.raises(AllocatorAuditError, match="double free"):
            a.release(got)

    def test_acquire_of_free_block_raises(self):
        a = BlockAllocator(2)
        with pytest.raises(AllocatorAuditError, match="no existing owner"):
            a.acquire([0])

    def test_foreign_ids_raise(self):
        a = BlockAllocator(2)
        with pytest.raises(AllocatorAuditError, match="foreign"):
            a.release([7])
        with pytest.raises(AllocatorAuditError, match="foreign"):
            a.refcount(-1)

    def test_free_is_release_alias(self):
        a = BlockAllocator(2)
        got = a.alloc(2)
        a.acquire([got[0]])
        a.free(got)                      # drops one owner each
        assert a.refcount(got[0]) == 1 and a.refcount(got[1]) == 0
        assert a.available == 1


# ---------------------------------------------------------------------------
class TestPrefixTrie:
    def _cache(self, nb=16):
        alloc = BlockAllocator(nb)
        return PrefixCache(BS, alloc), alloc

    def test_insert_match_roundtrip_full_blocks_only(self):
        pc, alloc = self._cache()
        toks = _prompt(2 * BS + 3)       # 2 full blocks + partial tail
        mine = alloc.alloc(3)
        pc.insert(toks, mine)            # only the 2 full blocks cache
        assert len(pc) == 2
        assert pc.match(toks) == mine[:2]
        assert pc.tokens_reused == 2 * BS
        # trie holds one ref per node on top of the row's own
        assert alloc.refcount(mine[0]) == 2
        assert alloc.refcount(mine[2]) == 1   # partial block never cached

    def test_match_leaves_at_least_one_token_to_prefill(self):
        pc, alloc = self._cache()
        toks = _prompt(2 * BS)           # exactly 2 blocks
        mine = alloc.alloc(2)
        pc.insert(toks, mine)
        # a feed of exactly the cached tokens may only map ONE block:
        # the last token must run through the model for its logits
        assert pc.match(toks) == mine[:1]
        assert pc.match(_prompt(2 * BS + 1)) == mine[:2]

    def test_reinsert_dedupes_without_extra_refs(self):
        pc, alloc = self._cache()
        toks = _prompt(BS)
        first = alloc.alloc(1)
        pc.insert(toks, first)
        second = alloc.alloc(1)          # a concurrent cold prefill's block
        added = pc.insert(toks, second)
        assert added == 0 and len(pc) == 1
        assert alloc.refcount(first[0]) == 2    # row + trie
        assert alloc.refcount(second[0]) == 1   # stays private to its row

    def test_lru_eviction_prefers_untouched_chain(self):
        pc, alloc = self._cache()
        a, b = _prompt(BS, lo=4), _prompt(BS, lo=5)
        blk_a, blk_b = alloc.alloc(1), alloc.alloc(1)
        pc.insert(a, blk_a)
        pc.insert(b, blk_b)
        alloc.release(blk_a)             # trie becomes sole owner of both
        alloc.release(blk_b)
        pc.match(np.concatenate([a, a[:1]]))    # touch chain a
        assert pc.evict(1) == 1
        assert alloc.refcount(blk_b[0]) == 0    # LRU victim was b
        assert alloc.refcount(blk_a[0]) == 1

    def test_children_evict_before_parents(self):
        pc, alloc = self._cache()
        toks = _prompt(3 * BS)
        mine = alloc.alloc(3)
        pc.insert(toks, mine)
        alloc.release(mine)              # trie sole owner of the chain
        pc.evict(1)
        # deepest node went first; the prefix above it still matches
        assert alloc.refcount(mine[2]) == 0
        assert pc.match(_prompt(3 * BS + 1)) == mine[:2]

    def test_evict_skips_blocks_live_rows_still_hold(self):
        pc, alloc = self._cache()
        toks = _prompt(BS)
        mine = alloc.alloc(1)
        pc.insert(toks, mine)            # refcount 2: row + trie
        assert pc.evictable() == 0
        assert pc.evict(5) == 0          # freeing nothing frees no memory
        alloc.release(mine)
        assert pc.evictable() == 1
        assert pc.evict(5) == 1
        assert alloc.available == alloc.num_blocks


# ---------------------------------------------------------------------------
class TestSharedPrefixBitwise:
    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_warm_equals_cold(self, setup, kv_int8):
        """A second admission of the same prompt maps the cached blocks
        and produces the cold admission's exact tokens (fp and int8-KV);
        both match a dense engine's output for the same request."""
        b = _engine(setup, kv_int8=kv_int8)
        p = _prompt(2 * BS + 5)
        b.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=6))
        _drain(b)
        assert b.prefix_cache.hits == 0 and len(b.prefix_cache) == 2
        b.submit(Request(uid=1, prompt=p.copy(), max_new_tokens=6))
        _drain(b)
        assert b.prefix_cache.hits == 1
        assert b.shared_tokens == 2 * BS
        cold, warm = b.done[0].output, b.done[1].output
        np.testing.assert_array_equal(cold, warm)
        if not kv_int8:
            d = _engine(setup, paged=False, prefix_cache=False)
            d.submit(Request(uid=2, prompt=p.copy(), max_new_tokens=6))
            _drain(d)
            np.testing.assert_array_equal(cold, d.done[0].output)

    def test_divergent_tail_only_prefills_the_tail(self, setup):
        """Prompts sharing 2 blocks then diverging reuse exactly the
        shared span and still match their own cold outputs."""
        b = _engine(setup)
        head = _prompt(2 * BS)
        pa = np.concatenate([head, _prompt(5, lo=20)])
        pb = np.concatenate([head, _prompt(7, lo=40)])
        b.submit(Request(uid=0, prompt=pa.copy(), max_new_tokens=5))
        _drain(b)
        b.submit(Request(uid=1, prompt=pb.copy(), max_new_tokens=5))
        _drain(b)
        assert b.prefix_cache.tokens_reused == 2 * BS
        cold = _engine(setup, prefix_cache=False)
        cold.submit(Request(uid=1, prompt=pb.copy(), max_new_tokens=5))
        _drain(cold)
        np.testing.assert_array_equal(b.done[1].output, cold.done[0].output)

    def test_cached_prompt_first_token_in_one_tick(self, setup):
        """A fully cached prompt runs ZERO prefill chunks for the shared
        span: one tick feeds the single remaining token and samples the
        first output token."""
        b = _engine(setup, prefill_chunk=BS)
        p = _prompt(3 * BS)              # block-aligned, 24 tokens
        b.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=4))
        cold_ticks_to_first = 0
        while not any(s.generated for s in b.slots):
            b.step()
            cold_ticks_to_first += 1
        assert cold_ticks_to_first == 3  # 24 tokens at 8/chunk
        _drain(b)
        b.submit(Request(uid=1, prompt=p.copy(), max_new_tokens=4))
        b.step()
        # after ONE tick the warm request has its first token: the match
        # is capped at 2 blocks ((24 - 1) // 8), so the tick fed exactly
        # the BS-token uncached tail — zero chunks for the shared span
        i, warm = next((i, s) for i, s in enumerate(b.slots)
                       if s.req is not None)
        assert warm.req.uid == 1
        assert warm.prefill is None and len(warm.generated) == 1
        assert warm.req.first_token_time is not None
        assert int(b.last_counts[i]) == BS
        _drain(b)
        np.testing.assert_array_equal(b.done[0].output, b.done[1].output)

    def test_preempt_swap_resume_row_holding_shared_blocks(self, setup):
        """Swap-preempting a row whose table maps trie-shared blocks
        copies them out rather than freeing them (the trie still owns
        them) and the resume is bitwise-exact."""
        b = _engine(setup, swap_break_even_tokens=4, batch_size=2)
        p = _prompt(2 * BS + 3)
        b.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=8))
        _drain(b)
        expect = b.done[0].output
        b.submit(Request(uid=1, prompt=p.copy(), max_new_tokens=8))
        for _ in range(3):               # bind (shared) + a couple decodes
            b.step()
        i = next(i for i, s in enumerate(b.slots)
                 if s.req is not None and s.req.uid == 1)
        shared = [blk for blk in b.slots[i].blocks
                  if b.allocator.refcount(blk) > 1]
        assert shared, "victim should be holding trie-shared blocks"
        b.preempt_slot(i)
        assert b.queue and b.queue[0].swapped is not None
        b.audit()
        # copied-not-freed: the trie still owns the shared blocks
        assert all(b.allocator.refcount(blk) == 1 for blk in shared)
        _drain(b)
        np.testing.assert_array_equal(b.done[1].output, expect)

    def test_eviction_never_blocks_admission(self, setup):
        """With the pool nearly all cached, a request needing more blocks
        than are free LRU-evicts cached prefixes and completes."""
        b = _engine(setup, num_blocks=6, batch_size=1, max_len=48)
        b.submit(Request(uid=0, prompt=_prompt(2 * BS + 1),
                         max_new_tokens=2))
        _drain(b)
        assert len(b.prefix_cache) == 2
        assert b.allocator.available == 4
        big = (np.arange(4 * BS + 1) % 40 + 10).astype(np.int32)
        b.submit(Request(uid=1, prompt=big, max_new_tokens=2))
        _drain(b)
        assert b.done[1].status == "done"
        assert b.prefix_cache.evictions >= 1
        b.audit()

    def test_transient_fault_does_not_flush_cache(self, setup):
        """An allocator denial while blocks are genuinely free must stall
        — not evict cached prefixes (the chaos contract)."""
        from repro.serving import FaultyAllocator
        b = _engine(setup)
        b.submit(Request(uid=0, prompt=_prompt(2 * BS + 1),
                         max_new_tokens=2))
        _drain(b)
        cached = len(b.prefix_cache)
        assert cached == 2
        b.allocator = FaultyAllocator(b.allocator)
        if b.prefix_cache is not None:
            b.prefix_cache.allocator = b.allocator
        b.allocator.failing = True
        b.submit(Request(uid=1, prompt=_prompt(3 * BS, lo=30),
                         max_new_tokens=2))
        for _ in range(3):
            b.step()                     # stalls, sheds nothing, evicts nothing
        assert len(b.prefix_cache) == cached
        b.allocator.failing = False
        _drain(b)
        assert b.done[1].status == "done"


# ---------------------------------------------------------------------------
class TestParallelSampling:
    GEN = GenerateConfig(temperature=0.8, top_k=8)

    def _independent(self, setup, p, n, base_seed, m=6, **kw):
        b = _engine(setup, gen=self.GEN, **kw)
        for i in range(n):
            b.submit(Request(uid=100 + i, prompt=p.copy(),
                             max_new_tokens=m, seed=base_seed + i))
        _drain(b)
        return {r.uid: r.output for r in b.done}

    @pytest.mark.parametrize("kv_int8", [False, True])
    def test_n_matches_independent_paged(self, setup, kv_int8):
        p = _prompt(2 * BS + 3)
        b = _engine(setup, gen=self.GEN, kv_int8=kv_int8)
        b.submit(Request(uid=7, prompt=p.copy(), max_new_tokens=6,
                         seed=42, n=3))
        _drain(b)
        parent = b.done[0]
        assert parent.status == "done" and len(parent.outputs) == 3
        assert b.cow_copies >= 1         # siblings diverged via CoW
        ind = self._independent(setup, p, 3, 42, kv_int8=kv_int8)
        for i in range(3):
            np.testing.assert_array_equal(parent.outputs[i], ind[100 + i])
        assert b.allocator.available == b.num_blocks - len(b.prefix_cache)

    def test_n_matches_independent_dense(self, setup):
        """Engines that cannot share (dense) run branches independently
        and still reproduce n independent requests exactly."""
        p = _prompt(11)
        b = _engine(setup, gen=self.GEN, paged=False, prefix_cache=False)
        b.submit(Request(uid=7, prompt=p.copy(), max_new_tokens=5,
                         seed=9, n=3))
        _drain(b)
        parent = b.done[0]
        ind = self._independent(setup, p, 3, 9, m=5, paged=False,
                                prefix_cache=False)
        for i in range(3):
            np.testing.assert_array_equal(parent.outputs[i], ind[100 + i])

    def test_default_seed_derives_from_uid(self, setup):
        """Without an explicit seed, branch i uses uid + i — the same
        rule independent requests with those seeds would need."""
        p = _prompt(BS + 2)
        b = _engine(setup, gen=self.GEN)
        b.submit(Request(uid=31, prompt=p.copy(), max_new_tokens=4, n=2))
        _drain(b)
        ind = self._independent(setup, p, 2, 31, m=4)
        for i in range(2):
            np.testing.assert_array_equal(b.done[0].outputs[i],
                                          ind[100 + i])

    def test_greedy_branches_agree(self, setup):
        """Greedy sampling is seed-independent: all branches must emit
        the single greedy continuation (the strongest internal
        consistency check on shared-prompt divergence)."""
        b = _engine(setup)
        p = _prompt(2 * BS + 1)
        b.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=6, n=3))
        _drain(b)
        outs = b.done[0].outputs
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])

    def test_cancel_cancels_every_branch(self, setup):
        b = _engine(setup, gen=self.GEN)
        p = _prompt(2 * BS + 3)
        b.submit(Request(uid=5, prompt=p.copy(), max_new_tokens=20,
                         seed=1, n=3))
        for _ in range(4):
            b.step()
        assert b.cancel(5)
        assert b.done == []
        parent = b.failed[-1]
        assert parent.uid == 5 and parent.status == "cancelled"
        assert len(parent.outputs) == 3
        b.audit()
        _drain(b)
        assert b.allocator.available == b.num_blocks - len(b.prefix_cache)

    def test_leader_promotion_on_branch_failure(self, setup):
        """If branches die while the group is mid-flight the rest still
        land and the parent aggregates the failure."""
        b = _engine(setup, gen=self.GEN)
        p = _prompt(BS + 4)
        b.submit(Request(uid=5, prompt=p.copy(), max_new_tokens=4,
                         seed=1, n=3))
        # kill a queued sibling before the leader publishes
        assert len(b.queue) == 3
        victim = b.queue.pop(-1)
        assert victim.branch == 2
        b._fail(victim, "shed")
        _drain(b)
        parent = b.failed[-1]
        assert parent.status == "shed"       # one branch failed
        assert len(parent.outputs) == 3
        # surviving branches still produced their exact continuations
        ind = self._independent(setup, p, 2, 1, m=4)
        np.testing.assert_array_equal(parent.outputs[0], ind[100])
        np.testing.assert_array_equal(parent.outputs[1], ind[101])


# ---------------------------------------------------------------------------
@pytest.mark.compile_budget(10)
def test_cow_adds_one_specialization_at_most(setup):
    """Copy-on-write is jitted separately from the decode tick with pow-2
    padded pair counts: a run with many CoW events stays inside the same
    compile envelope as the tick sweep budget plus ONE copy variant."""
    cfg, params = setup
    b = ContinuousBatcher(params, cfg, batch_size=4, max_len=64,
                          paged=True, block_size=BS, num_blocks=32,
                          prefix_cache=True, debug_audit=True,
                          gen=GenerateConfig(temperature=0.7, top_k=8))
    p = _prompt(2 * BS + 3)
    b.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=4, seed=3, n=3))
    _drain(b)
    b.submit(Request(uid=1, prompt=p.copy(), max_new_tokens=4, seed=5, n=2))
    _drain(b)
    assert b.cow_copies >= 3
