"""End-to-end PTQ system behaviour: the paper's core experimental claim —
a trained clipped-softmax/gated-attention model quantizes to W8A8 with a
small perplexity gap, while simulated outliers break the vanilla pipeline.
(Reduced-scale; the qualitative contrast is the invariant.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import opt_tiny
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import model_apply, model_init
from repro.quant import QConfig, QuantContext, calibrate, evaluate_perplexity
from repro.train.losses import clm_loss

KEY = jax.random.PRNGKey(0)
VOCAB, SEQ = 128, 32


def _apply_fn(cfg):
    def fn(params, batch, ctx):
        logits, _ = model_apply(params, cfg, batch, ctx=ctx)
        return logits
    return fn


def _loss_fn(cfg):
    def fn(params, batch, ctx):
        ctx = ctx if ctx is not None else QuantContext(None)
        logits, _ = model_apply(params, cfg, batch, ctx=ctx)
        return clm_loss(logits, jnp.asarray(batch["labels"]))
    return fn


@pytest.fixture(scope="module")
def setup():
    cfg = opt_tiny(vocab=VOCAB, seq_len=SEQ)
    params = model_init(KEY, cfg)
    data = SyntheticLM(SyntheticLMConfig(vocab_size=VOCAB, seq_len=SEQ,
                                         batch_size=4))
    return cfg, params, data


def test_calibrate_and_apply_close_to_fp(setup):
    cfg, params, data = setup
    qc = QConfig(weight_bits=8, act_bits=8)
    batches = [jax.tree_util.tree_map(jnp.asarray, data.batch(i))
               for i in range(4)]
    ctx = calibrate(_apply_fn(cfg), params, batches, qc, num_batches=4)
    assert len(ctx.ranges) > 10      # every layer contributed sites
    fp = evaluate_perplexity(_loss_fn(cfg), params,
                             batches, None, max_batches=2)
    q8 = evaluate_perplexity(_loss_fn(cfg), params,
                             batches, ctx, max_batches=2)
    # untrained network, but W8A8 of an outlier-free model stays close
    assert q8 < fp * 1.2


def test_outliers_break_w8a8(setup):
    """Inject a BERT-like outlier hidden dimension (scaled embedding
    column, so it rides the pre-LN residual through every layer) and watch
    per-tensor W8A8 degrade — the paper's Figure 1/Table 2 failure mode,
    reproduced structurally. The FP-vs-quantized gap of the clean model
    stays ~0; the outlier model picks up a multi-percent gap."""
    cfg, params, data = setup
    broken = jax.tree_util.tree_map(lambda x: x, params)
    # 1000x on a fixed channel puts the per-tensor ranges far past the
    # useful grid (x100 only produced a ~1.02 gap — too close to the 1.03
    # assertion to demonstrate the failure mode robustly)
    broken["embed"]["table"] = broken["embed"]["table"].at[:, 7].mul(1000.0)
    batches = [jax.tree_util.tree_map(jnp.asarray, data.batch(i))
               for i in range(4)]
    qc = QConfig()
    ctx_ok = calibrate(_apply_fn(cfg), params, batches, qc, 4)
    ctx_bad = calibrate(_apply_fn(cfg), broken, batches, qc, 4)
    gap_ok = (evaluate_perplexity(_loss_fn(cfg), params, batches, ctx_ok, 2)
              / evaluate_perplexity(_loss_fn(cfg), params, batches, None, 2))
    gap_bad = (evaluate_perplexity(_loss_fn(cfg), broken, batches, ctx_bad, 2)
               / evaluate_perplexity(_loss_fn(cfg), broken, batches, None, 2))
    assert gap_ok < 1.01, gap_ok
    assert gap_bad > 1.03, gap_bad


@pytest.mark.slow
def test_bitwidth_sweep_monotone(setup):
    """Lower weight bits => higher (or equal) perplexity, W8A8 -> W4A8
    (paper Table 10 direction)."""
    cfg, params, data = setup
    batches = [jax.tree_util.tree_map(jnp.asarray, data.batch(i))
               for i in range(4)]
    ppls = {}
    for bits in (8, 4, 2):
        qc = QConfig(weight_bits=bits, act_bits=8, weight_estimator="mse")
        ctx = calibrate(_apply_fn(cfg), params, batches, qc, 2)
        ppls[bits] = evaluate_perplexity(_loss_fn(cfg), params, batches, ctx, 2)
    assert ppls[2] > ppls[8] * 0.99
