"""Property-based tests for the quantization substrate (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import (
    MinMaxEstimator, MSEEstimator, PercentileEstimator, QConfig, QuantContext,
    QuantSpec, RunningMinMaxEstimator, dequantize, fake_quant,
    quantization_error, quantize, scale_zero_point,
)

KEY = jax.random.PRNGKey(0)


def _sz(x, spec):
    return scale_zero_point(jnp.min(x), jnp.max(x), spec)


class TestQuantizer:
    @given(bits=st.sampled_from([4, 6, 8]), symmetric=st.booleans(),
           seed=st.integers(0, 2 ** 16), scale=st.floats(0.01, 100.0))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_error_bounded_by_half_step(self, bits, symmetric, seed, scale):
        """|x - fq(x)| <= s/2 for in-range values (Eq. 1 invariant)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * scale
        spec = QuantSpec(bits=bits, symmetric=symmetric)
        s, z = _sz(x, spec)
        err = jnp.abs(x - fake_quant(x, s, z, spec))
        assert float(jnp.max(err)) <= float(s) / 2 + 1e-6 * scale

    @given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2 ** 16))
    @settings(max_examples=30, deadline=None)
    def test_idempotent(self, bits, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (128,))
        spec = QuantSpec(bits=bits)
        s, z = _sz(x, spec)
        fq1 = fake_quant(x, s, z, spec)
        fq2 = fake_quant(fq1, s, z, spec)
        np.testing.assert_allclose(fq1, fq2, atol=1e-6)

    @given(seed=st.integers(0, 2 ** 16))
    @settings(max_examples=20, deadline=None)
    def test_quantize_dequantize_integer_grid(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3
        spec = QuantSpec(bits=8)
        s, z = _sz(x, spec)
        q = quantize(x, s, z, spec)
        assert q.dtype == jnp.int32
        assert int(q.min()) >= 0 and int(q.max()) <= 255
        np.testing.assert_allclose(
            dequantize(q, s, z, spec), fake_quant(x, s, z, spec), atol=1e-6)

    def test_out_of_range_values_clip(self):
        x = jnp.array([-1.0, 0.0, 1.0])
        spec = QuantSpec(bits=8)
        s, z = scale_zero_point(jnp.float32(-1.0), jnp.float32(1.0), spec)
        y = fake_quant(jnp.array([10.0]), s, z, spec)
        assert float(y[0]) <= 1.0 + float(s)

    def test_ste_gradient(self):
        """Identity gradient in range, zero outside (straight-through)."""
        x = jnp.array([-0.5, 0.0, 0.5, 100.0])
        spec = QuantSpec(bits=8)
        s, z = scale_zero_point(jnp.float32(-1.0), jnp.float32(1.0), spec)
        g = jax.grad(lambda t: jnp.sum(fake_quant(t, s, z, spec)))(x)
        np.testing.assert_allclose(g[:3], 1.0, atol=1e-6)
        assert float(g[3]) == 0.0

    def test_symmetric_grid_centered(self):
        spec = QuantSpec(bits=8, symmetric=True)
        s, z = scale_zero_point(jnp.float32(-2.0), jnp.float32(2.0), spec)
        assert float(z) == 128
        assert float(fake_quant(jnp.zeros(1), s, z, spec)[0]) == 0.0

    def test_per_channel(self):
        x = jnp.stack([jnp.linspace(-1, 1, 16), jnp.linspace(-10, 10, 16)])
        spec = QuantSpec(bits=8, symmetric=True, per_channel_axis=0)
        s, z = scale_zero_point(x.min(axis=1), x.max(axis=1), spec)
        fq = fake_quant(x, s, z, spec)
        err = jnp.abs(fq - x)
        # channel 0 uses a 10x finer grid
        assert float(err[0].max()) < float(err[1].max()) / 5


class TestEstimators:
    def test_minmax_exact(self):
        est = MinMaxEstimator()
        est.update(jnp.array([1.0, 5.0]))
        est.update(jnp.array([-3.0, 2.0]))
        lo, hi = est.finalize()
        assert float(lo) == -3.0 and float(hi) == 5.0

    def test_running_minmax_smooths(self):
        est = RunningMinMaxEstimator(momentum=0.9)
        for v in [1.0, 1.0, 100.0]:
            est.update(jnp.array([0.0, v]))
        _, hi = est.finalize()
        assert float(hi) < 100.0   # the spike is EMA-damped

    def test_percentile_robust_to_outliers(self):
        x = np.concatenate([np.random.default_rng(0).normal(size=100000),
                            np.array([1000.0])])
        est = PercentileEstimator(percentile=99.9)
        est.update(jnp.asarray(x))
        lo, hi = est.finalize()
        assert float(hi) < 10.0   # ignores the 1000.0 outlier

    def test_mse_beats_minmax_on_outliers(self):
        """MSE range search clips the outlier; min-max wastes the grid on it
        (the trade-off from paper Sec 2)."""
        x = jnp.concatenate([jax.random.normal(KEY, (4096,)),
                             jnp.array([200.0])])
        spec = QuantSpec(bits=8)
        mm = MinMaxEstimator(); mm.update(x)
        mse = MSEEstimator(spec); mse.update(x)
        e_mm = quantization_error(x, *scale_zero_point(*mm.finalize(), spec), spec)
        e_mse = quantization_error(x, *scale_zero_point(*mse.finalize(), spec), spec)
        assert float(e_mse) < float(e_mm)


class TestQuantContext:
    def test_collect_then_apply(self):
        qc = QConfig(weight_bits=8, act_bits=8)
        ctx = QuantContext(qc, "collect")
        x = jax.random.normal(KEY, (64,))
        for _ in range(3):
            ctx.act("layer0/mlp.in", x)
        ctx.finalize()
        y = ctx.act("layer0/mlp.in", x)
        assert float(jnp.max(jnp.abs(y - x))) > 0  # actually quantized
        assert float(jnp.max(jnp.abs(y - x))) < 0.1

    def test_skip_patterns(self):
        qc = QConfig(skip_patterns=(r".*lm_head.*",))
        ctx = QuantContext(qc, "apply")
        x = jax.random.normal(KEY, (8,))
        np.testing.assert_array_equal(ctx.act("lm_head.in", x), x)

    def test_weight_quant_on_the_fly(self):
        qc = QConfig()
        ctx = QuantContext(qc, "apply")
        w = jax.random.normal(KEY, (32, 32))
        wq = ctx.weight("layer0/q", w)
        assert float(jnp.max(jnp.abs(wq - w))) > 0
        assert float(jnp.max(jnp.abs(wq - w))) < 0.05
