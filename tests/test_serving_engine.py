"""Decode-engine semantics: EOS handling in the fused generate loop,
per-row (vector) decode positions, and scheduler cache-row isolation under
staggered arrivals — the contracts the continuous batcher is built on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.models.transformer import ModelConfig, init_cache, model_apply
from repro.serving import ContinuousBatcher, GenerateConfig, Request, generate
from repro.serving.decode import decode_one, prefill

KEY = jax.random.PRNGKey(0)


def _setup(vocab=64, B=3, max_len=64):
    cfg = dataclasses.replace(opt_tiny(vocab=vocab, seq_len=32), max_seq_len=64)
    params = model_init(KEY, cfg)
    return cfg, params


def _ref_rows(params, cfg, prompts, max_new):
    """Sequential greedy continuations, one request at a time."""
    return [np.asarray(generate(params, cfg, jnp.asarray(p)[None, :],
                                GenerateConfig(max_new_tokens=m))[0, len(p):])
            for p, m in zip(prompts, max_new)]


class TestGenerateEOS:
    def test_generate_stops_at_eos_and_pads(self):
        """Regression: the seed `generate` ignored gen.eos_id entirely."""
        cfg, params = _setup()
        prompt = np.arange(4, 10, dtype=np.int32)
        ref = _ref_rows(params, cfg, [prompt], [8])[0]
        eos = int(ref[2])                      # greedy prefix is deterministic
        out = generate(params, cfg, jnp.asarray(prompt)[None, :],
                       GenerateConfig(max_new_tokens=8, eos_id=eos))
        row = np.asarray(out)[0, len(prompt):]
        k = list(row).index(eos)
        assert k <= 2                          # stopped at (or before) the ref hit
        np.testing.assert_array_equal(row[:k + 1], ref[:k + 1])
        assert (row[k + 1:] == 0).all(), row   # pad_id after EOS

    def test_batch_rows_finish_independently(self):
        cfg, params = _setup()
        prompts = np.stack([np.arange(4, 10), np.arange(9, 3, -1)]).astype(np.int32)
        refs = [np.asarray(generate(params, cfg, prompts[i:i + 1],
                                    GenerateConfig(max_new_tokens=6))[0, 6:])
                for i in range(2)]
        # pick an EOS that appears mid-stream in row 0 but not in row 1
        eos = next((int(t) for t in refs[0][:-1] if t not in refs[1]), None)
        if eos is None:
            pytest.skip("no distinguishing token for this seed")
        out = np.asarray(generate(params, cfg, jnp.asarray(prompts),
                                  GenerateConfig(max_new_tokens=6, eos_id=eos)))
        row0, row1 = out[0, 6:], out[1, 6:]
        k = list(row0).index(eos)
        np.testing.assert_array_equal(row0[:k + 1], refs[0][:k + 1])
        assert (row0[k + 1:] == 0).all()
        np.testing.assert_array_equal(row1, refs[1])  # unaffected row runs on

    def test_no_eos_runs_to_budget(self):
        cfg, params = _setup()
        prompt = np.arange(4, 10, dtype=np.int32)
        out = generate(params, cfg, jnp.asarray(prompt)[None, :],
                       GenerateConfig(max_new_tokens=5))
        assert out.shape == (1, len(prompt) + 5)


class TestPerRowDecode:
    @pytest.mark.slow
    def test_vector_pos_matches_scalar_decode(self):
        """One fused step with per-row positions == row-by-row scalar
        decode (the masked per-row scatter contract)."""
        cfg, params = _setup()
        prompts = [np.arange(4, 12), np.arange(5, 9), np.arange(3, 13)]
        L = 32
        pool = init_cache(cfg, len(prompts), L)
        toks, pos = [], []
        rows = []
        for p in prompts:
            ll, c, t = prefill(params, cfg, jnp.asarray(p, jnp.int32)[None, :], L)
            rows.append(c)
            toks.append(int(jnp.argmax(ll[0])))
            pos.append(t)

        def insert(i):
            def f(path, pool_leaf, row_leaf):
                return pool_leaf.at[i].set(row_leaf[0])
            return f
        for i, c in enumerate(rows):
            pool = jax.tree_util.tree_map_with_path(insert(i), pool, c)

        # fused per-row step
        lg, _ = decode_one(params, cfg, pool, jnp.asarray(toks, jnp.int32)[:, None],
                           jnp.asarray(pos, jnp.int32),
                           active=jnp.ones((3,), bool))
        fused = np.asarray(jnp.argmax(lg, -1))
        # scalar reference, row at a time
        for i, c in enumerate(rows):
            lg1, _ = decode_one(params, cfg, c,
                                jnp.asarray([[toks[i]]], jnp.int32), pos[i])
            assert int(jnp.argmax(lg1[0])) == fused[i]

    def test_inactive_rows_do_not_write(self):
        """active=False rows leave cache and state untouched (no
        double-buffer restore needed)."""
        cfg, params = _setup()
        cache = init_cache(cfg, 2, 32)
        toks = jnp.asarray([[5], [9]], jnp.int32)
        posv = jnp.asarray([3, 7], jnp.int32)
        _, aux = model_apply(params, cfg, {"tokens": toks}, cache=cache,
                             pos=posv, active=jnp.asarray([True, False]))
        for (_, new), (_, old) in zip(
                jax.tree_util.tree_leaves_with_path(aux["cache"]),
                jax.tree_util.tree_leaves_with_path(cache)):
            new, old = np.asarray(new), np.asarray(old)
            if new.shape[0] == 2:   # batch-leading leaf
                np.testing.assert_array_equal(new[1], old[1])


class TestSchedulerEndToEnd:
    @pytest.mark.slow
    def test_staggered_arrivals_mixed_lengths_eos(self):
        """Staggered arrivals + mixed prompt lengths + EOS mid-stream: every
        request's output is identical to a dedicated sequential generate,
        and every active slot advances every tick (no lockstep cohorts)."""
        cfg, params = _setup()
        rng = np.random.default_rng(3)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (5, 3, 8, 4, 6)]
        max_new = [6, 8, 5, 7, 6]
        refs = _ref_rows(params, cfg, prompts, max_new)
        # an EOS that request 0 emits mid-stream (others may or may not)
        eos = int(refs[0][2])
        expected = []
        for r in refs:
            hits = np.flatnonzero(r == eos)
            expected.append(r[:hits[0] + 1] if hits.size else r)

        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              eos_id=eos)
        b.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=max_new[0]))
        b.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=max_new[1]))
        n_active = [b.step(), b.step()]
        for uid in (2, 3, 4):
            b.submit(Request(uid=uid, prompt=prompts[uid],
                             max_new_tokens=max_new[uid]))
        done = sorted(b.run(), key=lambda r: r.uid)
        assert len(done) == 5
        # both slots decoded together on the first tick despite different
        # positions (no lockstep cohorts); later ticks may shrink via EOS
        assert n_active[0] == 2
        for req, exp in zip(done, expected):
            np.testing.assert_array_equal(req.output, exp, err_msg=f"uid={req.uid}")

    def test_no_tick_clobbers_other_slots_cache(self):
        """Admitting + decoding a new request must not touch another slot's
        cache row (history) — the bug class the seed's double-buffer
        restore papered over."""
        cfg, params = _setup()
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64)
        p0 = np.arange(4, 10, dtype=np.int32)
        b.submit(Request(uid=0, prompt=p0, max_new_tokens=10))
        b.step()
        b.step()

        def kv_row(cache, i):
            out = []
            for g in cache["layers"]:
                for blk in g.values():
                    out.append((np.asarray(blk["k"])[i], np.asarray(blk["v"])[i]))
            return out

        before = kv_row(b.cache, 0)
        pos0 = b.slots[0].pos
        b.submit(Request(uid=1, prompt=np.arange(3, 11, dtype=np.int32),
                         max_new_tokens=4))
        b.step()    # admits uid=1 into slot 1 AND decodes both
        after = kv_row(b.cache, 0)
        for (kb, vb), (ka, va) in zip(before, after):
            # slot 0's history below its own write position is untouched
            np.testing.assert_array_equal(kb[:pos0], ka[:pos0])
            np.testing.assert_array_equal(vb[:pos0], va[:pos0])
            # ...and its own decode write did land this tick
            assert np.any(ka[pos0] != kb[pos0]) or np.any(va[pos0] != vb[pos0])

class TestBatcherSampling:
    """GenerateConfig parity in the fused tick: temperature/top-k sampling
    with per-request seeds, position-keyed so scheduling cannot change a
    request's continuation."""

    def _run(self, params, cfg, prompts, max_new, seeds, **kw):
        b = ContinuousBatcher(params, cfg,
                              gen=GenerateConfig(temperature=0.8, top_k=16),
                              **kw)
        for u, (p, m) in enumerate(zip(prompts, max_new)):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=m,
                             seed=seeds[u]))
        return {r.uid: r.output for r in b.run()}

    @pytest.mark.slow
    def test_seeded_sampling_invariant_to_scheduling(self):
        """Same seeds -> identical outputs across batch sizes and cache
        backends: the sample at position p is fold_in(seed, p), a pure
        function of the request, not of slot assignment or tick order."""
        cfg, params = _setup()
        rng = np.random.default_rng(11)
        prompts = [rng.integers(4, 60, size=n).astype(np.int32)
                   for n in (5, 3, 8)]
        max_new = [6, 8, 5]
        seeds = [101, 102, 103]
        ref = self._run(params, cfg, prompts, max_new, seeds,
                        batch_size=2, max_len=32)
        for kw in (dict(batch_size=3, max_len=32),
                   dict(batch_size=2, max_len=32, paged=True, block_size=8)):
            out = self._run(params, cfg, prompts, max_new, seeds, **kw)
            for u in ref:
                np.testing.assert_array_equal(out[u], ref[u],
                                              err_msg=f"uid={u} {kw}")

    @pytest.mark.slow
    def test_sampled_preemption_resumes_exactly(self):
        """Recompute-preemption under temperature sampling: position-keyed
        draws make the resumed continuation identical to an un-preempted
        run (the sampling analogue of the greedy resume guarantee)."""
        cfg, params = _setup()
        rng = np.random.default_rng(7)
        prompts = [rng.integers(4, 60, size=8).astype(np.int32)
                   for _ in range(2)]
        max_new = [12, 12]
        seeds = [5, 6]
        roomy = self._run(params, cfg, prompts, max_new, seeds,
                          batch_size=2, max_len=32, paged=True, block_size=4)
        # 6-block pool: both rows grow to 5 blocks -> forced preemption
        tight = self._run(params, cfg, prompts, max_new, seeds,
                          batch_size=2, max_len=32, paged=True, block_size=4,
                          num_blocks=6)
        for u in roomy:
            np.testing.assert_array_equal(tight[u], roomy[u], err_msg=f"uid={u}")

    def test_greedy_default_ignores_seed(self):
        cfg, params = _setup()
        p = np.arange(4, 10, dtype=np.int32)
        ref = _ref_rows(params, cfg, [p], [4])[0]
        b = ContinuousBatcher(params, cfg, batch_size=1, max_len=32)
        b.submit(Request(uid=0, prompt=p, max_new_tokens=4, seed=123))
        np.testing.assert_array_equal(b.run()[0].output, ref)


class TestSchedulerScan:
    @pytest.mark.slow
    def test_scanned_layer_cache_insert(self):
        """Regression: prefill-row insertion must handle scanned caches,
        whose leaves stack layer groups in front of the batch axis."""
        cfg = ModelConfig(name="scan", n_layers=2, d_model=32, n_heads=2,
                          n_kv_heads=2, d_ff=64, vocab_size=32,
                          pos="rope", max_seq_len=64, scan_layers=True,
                          remat=False, mlp_kind="swiglu", norm="rmsnorm")
        params = model_init(KEY, cfg)
        p = np.arange(4, 9, dtype=np.int32)
        ref = np.asarray(generate(params, cfg, jnp.asarray(p)[None, :],
                                  GenerateConfig(max_new_tokens=4))[0, len(p):])
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=32)
        b.submit(Request(uid=0, prompt=p, max_new_tokens=4))
        done = b.run()
        np.testing.assert_array_equal(done[0].output, ref)
