"""Sharding rules + a miniature dry-run in a subprocess (the device count
must be forced before jax initializes, so multi-device tests run isolated).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import param_rules, spec_for_path
from repro.launch.mesh import make_host_mesh
from repro.models import model_init

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestRules:
    def setup_method(self):
        self.mesh = make_host_mesh()
        self.rules = param_rules("tp_fsdp", self.mesh)

    def test_attention_projections(self):
        assert spec_for_path("layers/0/b0/q/w", self.rules, False) == \
            P("data", "model")
        assert spec_for_path("layers/0/b0/o/w", self.rules, False) == \
            P("model", "data")

    def test_scanned_groups_get_leading_none(self):
        s = spec_for_path("groups/b0/q/w", self.rules, True)
        assert s == P(None, "data", "model")

    def test_embed_vocab_sharded(self):
        assert spec_for_path("embed/table", self.rules, False) == \
            P("model", None)

    def test_norms_replicated(self):
        assert spec_for_path("layers/3/b0/ln1/scale", self.rules, False) == P()

    def test_moe_expert_dims(self):
        assert spec_for_path("groups/b0/moe/w_gate", self.rules, True) == \
            P(None, None, "data", "model")
        assert spec_for_path("groups/b0/moe/w_down", self.rules, True) == \
            P(None, None, "model", "data")

    def test_gate_params_replicated(self):
        assert spec_for_path("layers/0/b0/gate/w", self.rules, False) == P()

    def test_tp_only_profile_drops_fsdp(self):
        rules = param_rules("tp_only", self.mesh)
        assert spec_for_path("layers/0/b0/q/w", rules, False) == \
            P(None, "model")

    def test_every_param_of_every_arch_gets_valid_spec(self):
        """No rule emits a spec longer than the tensor rank, for any arch."""
        from repro.distributed.sharding import tree_param_specs
        from repro.nn.module import flatten_params
        for arch in ("granite-moe-1b-a400m", "gemma2-27b", "xlstm-1.3b",
                     "recurrentgemma-9b", "hubert-xlarge"):
            cfg = get_arch(arch).smoke()
            shapes = jax.eval_shape(
                lambda c=cfg: model_init(jax.random.PRNGKey(0), c))
            specs = tree_param_specs(shapes, "tp_fsdp", self.mesh)
            for (path, leaf), spec in zip(
                    flatten_params(shapes),
                    jax.tree_util.tree_leaves(
                        specs, is_leaf=lambda x: isinstance(x, P))):
                assert len(spec) <= leaf.ndim, (arch, path, spec)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json, dataclasses, jax
    from repro.configs import SHAPES, get_arch, apply_method
    from repro.launch.dryrun import build_lowered
    from repro.launch.roofline import analyze
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4, 4), ("data", "model"))
    spec = get_arch("{arch}")
    # reduced-width full-family config so the 16-dev compile is fast
    cfg = apply_method(spec.smoke(), "clipped_softmax")
    cfg = dataclasses.replace(cfg, scan_layers=True, remat=True,
                              max_seq_len=SHAPES["{shape}"].seq_len + 8)
    shape = dataclasses.replace(SHAPES["{shape}"], seq_len=64, global_batch=8)
    compiled = build_lowered(cfg, shape, mesh, "tp_fsdp").compile()
    roof = analyze(compiled, 16)
    print(json.dumps({{"ok": True, "bottleneck": roof.bottleneck,
                       "flops": roof.flops_per_device}}))
""")


@pytest.mark.slow  # forced 16-device subprocess compile per cell
@pytest.mark.parametrize("arch,shape", [
    ("granite-moe-1b-a400m", "train_4k"),
    ("deepseek-67b", "decode_32k"),
    ("recurrentgemma-9b", "prefill_32k"),
    ("xlstm-1.3b", "train_4k"),
])
def test_mini_dryrun_subprocess(arch, shape):
    """Lower+compile a reduced cell on a forced 16-device host mesh —
    validates the whole sharding pipeline without the 512-dev cost."""
    code = MINI_DRYRUN.format(arch=arch, shape=shape)
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["flops"] > 0
