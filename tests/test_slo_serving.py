"""SLO-aware scheduling + swapped preemption: swap-resume bitwise equal to
recompute-resume (fp and int8-KV), the bytes-vs-recompute cost rule,
bounded swap-in-denial degradation, mid-prefill cancellation on dense /
paged / int8-KV backends, and deadline/timeout eviction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import (
    ContinuousBatcher,
    GenerateConfig,
    Request,
    generate,
)

KEY = jax.random.PRNGKey(0)


def _setup(max_len=64):
    cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                              max_seq_len=max_len)
    return cfg, model_init(KEY, cfg)


def _refs(params, cfg, prompts, max_new):
    return [np.asarray(generate(params, cfg, jnp.asarray(p)[None, :],
                                GenerateConfig(max_new_tokens=m))[0, len(p):])
            for p, m in zip(prompts, max_new)]


def _prompts(n, size=8, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 60, size=size).astype(np.int32)
            for _ in range(n)]


def _drain(b, ticks=400):
    while (b.queue or any(s.req is not None for s in b.slots)) and ticks:
        b.step()
        ticks -= 1
    assert ticks, "engine failed to drain"
    return {r.uid: r.output for r in b.done}


def _preempted_run(params, cfg, prompts, max_new, *, swap, kv_int8=False,
                   warm_ticks=6):
    """Run with a forced preemption of slot 0 after ``warm_ticks``; swap
    on/off toggles the resume mechanism, everything else identical."""
    b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64, paged=True,
                          block_size=4, num_blocks=16, kv_int8=kv_int8,
                          swap_break_even_tokens=0 if swap else None,
                          debug_audit=True)
    for u, (p, m) in enumerate(zip(prompts, max_new)):
        b.submit(Request(uid=u, prompt=p, max_new_tokens=m))
    for _ in range(warm_ticks):
        b.step()
    assert b.slots[0].req is not None
    victim = b.slots[0].req
    b.preempt_slot(0)
    if swap:
        assert victim.swapped is not None, "cost rule should pick swap"
    else:
        assert victim.swapped is None
    out = _drain(b)
    assert b.allocator.available == b.num_blocks
    b.audit()
    return out


class TestSwappedPreemption:
    def test_swap_resume_bitwise_equals_recompute_fp(self):
        cfg, params = _setup()
        prompts, max_new = _prompts(2), [12, 12]
        refs = _refs(params, cfg, prompts, max_new)
        swap = _preempted_run(params, cfg, prompts, max_new, swap=True)
        reco = _preempted_run(params, cfg, prompts, max_new, swap=False)
        for u in range(2):
            np.testing.assert_array_equal(swap[u], reco[u], err_msg=f"uid={u}")
            np.testing.assert_array_equal(swap[u], refs[u], err_msg=f"uid={u}")

    def test_swap_resume_bitwise_equals_recompute_int8(self):
        """int8-KV: quantize-on-write makes pool bits a pure function of
        (value, position), so a swapped-out block row must restore
        bit-identically and the resumed request must emit exactly the
        tokens of both the recompute path and an unpreempted engine."""
        cfg, params = _setup()
        prompts, max_new = _prompts(2, seed=5), [12, 12]
        swap = _preempted_run(params, cfg, prompts, max_new, swap=True,
                              kv_int8=True)
        reco = _preempted_run(params, cfg, prompts, max_new, swap=False,
                              kv_int8=True)
        # unpreempted oracle on the same int8 engine
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              paged=True, block_size=4, num_blocks=16,
                              kv_int8=True)
        for u, (p, m) in enumerate(zip(prompts, max_new)):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=m))
        oracle = _drain(b)
        for u in range(2):
            np.testing.assert_array_equal(swap[u], reco[u], err_msg=f"uid={u}")
            np.testing.assert_array_equal(swap[u], oracle[u],
                                          err_msg=f"uid={u}")

    def test_cost_rule_thresholds_on_cached_tokens(self):
        """Victims below ``swap_break_even_tokens`` recompute (copying a
        few blocks costs more than re-prefilling them); above it they
        swap. Both shapes must resume exactly."""
        cfg, params = _setup()
        prompts, max_new = _prompts(2), [12, 12]
        refs = _refs(params, cfg, prompts, max_new)

        def run(threshold):
            b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                                  paged=True, block_size=4, num_blocks=16,
                                  swap_break_even_tokens=threshold,
                                  debug_audit=True)
            for u, (p, m) in enumerate(zip(prompts, max_new)):
                b.submit(Request(uid=u, prompt=p, max_new_tokens=m))
            for _ in range(4):
                b.step()
            victim = b.slots[0].req
            pos = b.slots[0].pos
            b.preempt_slot(0)
            took_swap = victim.swapped is not None  # consumed at swap-in
            out = _drain(b)
            return took_swap, pos, out

        swapped_lo, pos, out_lo = run(1)       # pos >= 1 -> swap
        assert swapped_lo and pos >= 1
        swapped_hi, _, out_hi = run(10_000)    # pos < 10k -> recompute
        assert not swapped_hi
        for u in range(2):
            np.testing.assert_array_equal(out_lo[u], refs[u])
            np.testing.assert_array_equal(out_hi[u], refs[u])

    def test_swap_in_denial_degrades_to_recompute(self):
        """A victim whose swap-in keeps being denied burns its bounded
        retry budget, drops the host copy, and resumes via recompute —
        still token-exact, no leak, no livelock."""
        cfg, params = _setup()
        prompts, max_new = _prompts(2), [12, 12]
        refs = _refs(params, cfg, prompts, max_new)
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              paged=True, block_size=4, num_blocks=16,
                              swap_break_even_tokens=0, swap_retry_limit=2,
                              debug_audit=True)
        for u, (p, m) in enumerate(zip(prompts, max_new)):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=m))
        for _ in range(6):
            b.step()
        victim = b.slots[0].req
        b.preempt_slot(0)
        assert victim.swapped is not None
        b._swap_in_gate = lambda req: False     # deny every swap-in
        for _ in range(8):
            b.step()
        assert victim.swapped is None, "retry budget must be bounded"
        assert b._swap_bytes == 0
        b._swap_in_gate = None
        out = _drain(b)
        for u in range(2):
            np.testing.assert_array_equal(out[u], refs[u], err_msg=f"uid={u}")


class TestMidPrefillCancel:
    """A request cancelled partway through chunked prefill must free its
    blocks and drop its remaining chunks the same tick, on every backend,
    and never perturb its neighbours."""

    def _run(self, kv_int8=False, paged=True):
        cfg, params = _setup()
        long_p = _prompts(1, size=24, seed=11)[0]
        short_p = _prompts(1, size=6, seed=12)[0]
        (ref,) = _refs(params, cfg, [short_p], [8])
        kw = dict(batch_size=2, max_len=64, token_budget=8,
                  debug_audit=paged)
        if paged:
            kw.update(paged=True, block_size=4, num_blocks=16,
                      kv_int8=kv_int8)
        b = ContinuousBatcher(params, cfg, **kw)
        b.submit(Request(uid=0, prompt=long_p, max_new_tokens=8))
        b.submit(Request(uid=1, prompt=short_p, max_new_tokens=8))
        b.step()     # token_budget=8 < 24: uid0 is now mid-prefill
        mid = next(s for s in b.slots if s.req is not None
                   and s.req.uid == 0)
        assert mid.prefill is not None and mid.prefill.done > 0
        assert b.cancel(0)
        # same tick: slot empty, blocks back, tables clear, audit clean
        assert all(s.req is None or s.req.uid != 0 for s in b.slots)
        if paged:
            held = sum(len(s.blocks) for s in b.slots)
            assert b.allocator.available == b.num_blocks - held
            b.audit()
        (cancelled,) = b.failed
        assert cancelled.uid == 0 and cancelled.status == "cancelled"
        out = _drain(b)
        assert 0 not in out
        np.testing.assert_array_equal(out[1], ref)
        if paged:
            assert b.allocator.available == b.num_blocks

    def test_dense(self):
        self._run(paged=False)

    def test_paged(self):
        self._run(paged=True)

    def test_paged_int8(self):
        self._run(paged=True, kv_int8=True)


class TestDeadlines:
    def test_queued_request_expires_before_admission(self):
        cfg, params = _setup()
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              paged=True, block_size=4, num_blocks=16)
        b.submit(Request(uid=0, prompt=np.arange(4, 10, dtype=np.int32),
                         max_new_tokens=4, deadline=0.5))
        b.step(now=1.0)      # clock already past the deadline
        assert not b.queue and not b.done
        (req,) = b.failed
        assert req.status == "expired" and req.finish_time == 1.0

    def test_running_request_times_out_and_frees_blocks(self):
        cfg, params = _setup()
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              paged=True, block_size=4, num_blocks=16,
                              debug_audit=True)
        b.submit(Request(uid=0, prompt=np.arange(4, 10, dtype=np.int32),
                         max_new_tokens=500, timeout=2.0))
        for t in (0.0, 1.0, 2.0, 3.0):
            b.step(now=t)
        (req,) = b.failed
        assert req.status == "timeout"
        assert len(req.output) > 0          # partial tokens delivered
        assert b.allocator.available == b.num_blocks
        b.audit()

    def test_deadline_met_requests_unaffected(self):
        cfg, params = _setup()
        prompts, max_new = _prompts(2), [8, 8]
        refs = _refs(params, cfg, prompts, max_new)
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              paged=True, block_size=4, num_blocks=16)
        for u, (p, m) in enumerate(zip(prompts, max_new)):
            b.submit(Request(uid=u, prompt=p, max_new_tokens=m,
                             deadline=1e9))
        out = _drain(b)
        for u in range(2):
            np.testing.assert_array_equal(out[u], refs[u])


class TestPrefillBudget:
    def test_prefill_budget_caps_prefill_tokens_per_tick(self):
        cfg, params = _setup()
        long_p = _prompts(1, size=24, seed=21)[0]
        b = ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              token_budget=32, prefill_budget=4,
                              paged=True, block_size=4, num_blocks=16)
        b.submit(Request(uid=0, prompt=long_p, max_new_tokens=2))
        b.step()
        assert b.last_tick_tokens <= 4
        s = next(s for s in b.slots if s.req is not None)
        assert s.prefill is not None and s.prefill.done <= 4
