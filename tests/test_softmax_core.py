"""Unit + property tests for the paper's core math (clipped softmax,
gating, outlier metrics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.softmax import (
    ClippedSoftmaxConfig, clipped_softmax, softcap, softmax, stretch_and_clip,
)
from repro.core.gating import GateConfig, gate_param_count, gate_probs, init_gate
from repro.core.outliers import (
    infinity_norm, kurtosis, outlier_counts_by_dim, outlier_mask,
)

KEY = jax.random.PRNGKey(0)


class TestClippedSoftmax:
    def test_vanilla_equivalence_at_gamma0(self):
        x = jax.random.normal(KEY, (4, 32))
        np.testing.assert_allclose(
            clipped_softmax(x, gamma=0.0, zeta=1.0), softmax(x), atol=1e-7)

    def test_exact_zeros_with_finite_range(self):
        """The paper's central claim: gamma < 0 makes exact zeros reachable
        with a FINITE logit range (Eq. 2 shows vanilla softmax cannot)."""
        x = jnp.array([[0.0, 1.0, 6.0, 6.0]])
        p = clipped_softmax(x, gamma=-0.03)
        assert p[0, 0] == 0.0 and p[0, 1] == 0.0
        assert softmax(x)[0, 0] > 0.0  # vanilla can't represent the zero

    def test_exact_ones_with_zeta(self):
        x = jnp.array([[10.0, 0.0, 0.0, 0.0]])
        p = clipped_softmax(x, gamma=0.0, zeta=1.1)
        assert p[0, 0] == 1.0

    def test_clipped_entries_get_zero_gradient(self):
        """Clipping stops the gradient that grows outliers (paper Sec 4.1)."""
        x = jnp.array([0.0, 1.0, 8.0, 8.0])
        g = jax.grad(lambda t: clipped_softmax(t, gamma=-0.03)[0])(x)
        np.testing.assert_allclose(g, jnp.zeros_like(g), atol=1e-9)
        g_v = jax.grad(lambda t: softmax(t)[0])(x)
        assert float(jnp.max(jnp.abs(g_v))) > 0  # vanilla keeps pushing

    def test_gamma_from_alpha(self):
        cfg = ClippedSoftmaxConfig(alpha=4.0)
        assert cfg.resolve_gamma(128) == pytest.approx(-4.0 / 128)
        assert not cfg.is_vanilla

    def test_masked_positions_stay_zero(self):
        x = jax.random.normal(KEY, (2, 8))
        where = jnp.arange(8) < 5
        p = stretch_and_clip(softmax(x, where=where), -0.05, 1.0)
        assert float(jnp.max(jnp.abs(p[:, 5:]))) == 0.0

    @given(gamma=st.floats(-0.2, 0.0), zeta=st.floats(1.0, 1.2),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=30, deadline=None)
    def test_range_property(self, gamma, zeta, seed):
        """Output always in [0, 1]; monotone in the input logit."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (3, 16)) * 5
        p = clipped_softmax(x, gamma=gamma, zeta=zeta)
        assert float(jnp.min(p)) >= 0.0 and float(jnp.max(p)) <= 1.0

    @given(cap=st.floats(1.0, 100.0), seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_softcap_bounds(self, cap, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 1000
        y = softcap(x, cap)
        assert float(jnp.max(jnp.abs(y))) <= cap * 1.0001


class TestGating:
    @pytest.mark.parametrize("kind", ["linear", "mlp", "all_heads_linear"])
    def test_shapes_and_range(self, kind):
        h, dh, dm, b, t = 4, 16, 64, 2, 8
        cfg = GateConfig(kind=kind, n_hid=4)
        p = init_gate(KEY, cfg, h, dh, dm)
        xh = jax.random.normal(KEY, (b, t, h, dh))
        xm = xh.reshape(b, t, dm)
        pi = gate_probs(p, cfg, xh, xm)
        assert pi.shape == (b, t, h)
        assert float(pi.min()) >= 0.0 and float(pi.max()) <= 1.0

    def test_pi_init_controls_initial_gate(self):
        """Paper Sec 5.3: bias init sets the initial gate probability."""
        for pi_target in (0.1, 0.5, 0.9):
            cfg = GateConfig.from_pi_init(pi_target)
            p = init_gate(KEY, cfg, 4, 16, 64)
            p = jax.tree_util.tree_map(
                lambda a: jnp.zeros_like(a) if a.ndim > 1 else a, p)
            xh = jax.random.normal(KEY, (1, 4, 4, 16))
            pi = gate_probs(p, cfg, xh, xh.reshape(1, 4, 64))
            np.testing.assert_allclose(pi, pi_target, atol=1e-5)

    def test_param_count_matches_table4(self):
        """BERT-base linear gate: n_heads*(d_head+1) = 12*65 = 780 params,
        <0.009%% of 109M (paper footnote 6)."""
        assert gate_param_count(GateConfig("linear"), 12, 64, 768) == 780
        assert gate_param_count(GateConfig("mlp", n_hid=4), 12, 64, 768) \
            == 12 * (4 * 66 + 1)
        assert gate_param_count(GateConfig("all_heads_linear"), 12, 64, 768) \
            == 12 * 769

    def test_finetuning_scale(self):
        """App B.6: output_scale=2 with b_init=0 gives expected gate 1."""
        cfg = GateConfig(kind="linear", b_init=0.0, output_scale=2.0)
        p = init_gate(KEY, cfg, 2, 8, 16)
        p = jax.tree_util.tree_map(
            lambda a: jnp.zeros_like(a) if a.ndim > 1 else a, p)
        xh = jax.random.normal(KEY, (1, 3, 2, 8))
        pi = gate_probs(p, cfg, xh, xh.reshape(1, 3, 16))
        np.testing.assert_allclose(pi, 1.0, atol=1e-6)


class TestOutlierMetrics:
    def test_inf_norm(self):
        x = jnp.array([[1.0, -7.5], [2.0, 3.0]])
        assert float(infinity_norm(x)) == 7.5

    def test_kurtosis_gaussian_vs_outliers(self):
        x = jax.random.normal(KEY, (10000,))
        k_g = float(kurtosis(x))
        assert 2.5 < k_g < 3.5           # gaussian ~ 3
        x_out = x.at[0].set(100.0)
        assert float(kurtosis(x_out)) > 100.0

    def test_outlier_counts_localized(self):
        x = jax.random.normal(KEY, (4, 16, 32)) * 0.1
        x = x.at[:, 3, 7].set(50.0)   # sparse spike in one hidden dim
        counts = outlier_counts_by_dim(x, n_sigma=6.0)
        assert int(counts[7]) == 4
        assert int(counts.sum()) == 4

    @given(seed=st.integers(0, 1000), scale=st.floats(0.1, 10.0))
    @settings(max_examples=20, deadline=None)
    def test_outlier_mask_scale_invariant(self, seed, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (256,))
        m1 = outlier_mask(x, 6.0)
        m2 = outlier_mask(x * scale, 6.0)
        assert bool(jnp.all(m1 == m2))
