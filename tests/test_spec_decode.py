"""Speculative decoding: n-gram drafting + k-token verification is
LOSSLESS — spec-on vs spec-off bitwise token equality across
dense/paged x fp/int8-KV x greedy/sampled x chunked-prefill x
prefix-cache-warm admission x parallel sampling, EOS/max_new truncation
inside an accepted run, multi-block-boundary ticks, fed-vs-banked
accounting, and chaos storms (preempt/swap/cancel) mid-speculation with
a clean allocator audit and token-exact survivors."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import ModelConfig, model_init
from repro.serving import (
    ChaosHarness,
    ContinuousBatcher,
    FaultPlan,
    GenerateConfig,
    NGramDrafter,
    Request,
    SpecConfig,
    TickCostModel,
    TraceEntry,
    generate,
    run_workload,
)

KEY = jax.random.PRNGKey(0)


def _tiny(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                d_ff=64, vocab_size=64, pos="rope", max_seq_len=1024,
                scan_layers=False, remat=False, mlp_kind="swiglu",
                norm="rmsnorm")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny()
    return cfg, model_init(KEY, cfg)


def _engine(setup, **kw):
    cfg, params = setup
    base = dict(batch_size=4, max_len=96, paged=True, block_size=8,
                num_blocks=56, debug_audit=True)
    base.update(kw)
    return ContinuousBatcher(params, cfg, **base)


def _motif_prompt(n, motif=(3, 7, 11, 5)):
    """Repetitive prompt: the drafter's n-gram lookup fires on it, and a
    tiny greedy model's continuation is repetitive too, so acceptance is
    actually exercised (tests assert it is, so equality is non-vacuous)."""
    reps = -(-n // len(motif))
    return np.asarray((list(motif) * reps)[:n], np.int32)


def _reqs(k=3, plen=24, max_new=20, seeds=False):
    return [Request(uid=u, prompt=_motif_prompt(plen + u),
                    max_new_tokens=max_new,
                    seed=100 + u if seeds else None) for u in range(k)]


def _outs(b):
    return {r.uid: r.output.tolist() for r in b.done}


def _run(setup, reqs, spec=None, **kw):
    b = _engine(setup, spec=spec, **kw)
    for r in reqs:
        b.submit(dataclasses.replace(r, prompt=r.prompt.copy(), output=None))
    b.run()
    if b.paged:
        b.audit()
    return b


def _assert_pair(setup, reqs, spec=SpecConfig(k=4), **kw):
    """spec-off vs spec-on engines over the same requests: outputs must
    be bitwise equal AND the speculative run must actually accept."""
    base = _run(setup, reqs, spec=None, **kw)
    spec_b = _run(setup, reqs, spec=spec, **kw)
    assert _outs(base) == _outs(spec_b)
    assert spec_b.spec_drafted > 0 and spec_b.spec_accepted > 0
    return base, spec_b


# ---------------------------------------------------------------------------
class TestDrafter:
    def test_prompt_lookup_continuation(self):
        d = NGramDrafter(SpecConfig(k=4, max_ngram=3))
        out = d.propose(np.asarray([1, 2, 3, 4, 1, 2], np.int32), [], 2)
        assert out == [3, 4]          # suffix [1,2] recurs at 0 -> [3,4]

    def test_most_recent_occurrence_wins(self):
        d = NGramDrafter(SpecConfig(k=1, max_ngram=2))
        out = d.propose(np.asarray([1, 2, 5, 1, 2, 7, 1, 2], np.int32),
                        [], 1)
        assert out == [7]             # match at index 3, not index 0

    def test_generated_history_is_searched(self):
        d = NGramDrafter(SpecConfig(k=3, max_ngram=2))
        out = d.propose(np.asarray([9, 8], np.int32), [4, 5, 6, 4, 5], 3)
        assert out == [6, 4, 5]       # suffix [4,5] recurs inside generated

    def test_no_match_and_min_context(self):
        d = NGramDrafter(SpecConfig(k=4, min_context=4))
        assert d.propose(np.asarray([1, 2, 3, 4, 5], np.int32), [], 4) == []
        assert d.propose(np.asarray([7, 7, 7], np.int32), [], 4) == []

    def test_k_truncates(self):
        d = NGramDrafter(SpecConfig(k=8, max_ngram=1))
        out = d.propose(_motif_prompt(12), [], 2)
        assert len(out) == 2

    def test_config_validation(self):
        with pytest.raises(ValueError):
            SpecConfig(k=0)
        with pytest.raises(ValueError):
            SpecConfig(min_ngram=3, max_ngram=2)
        with pytest.raises(ValueError):
            SpecConfig(min_context=0)


class TestGating:
    def test_ring_config_refused(self, setup):
        _, params = setup
        cfg = _tiny(pattern=("attn", "local_attn"), window=16)
        params = model_init(KEY, cfg)
        with pytest.raises(ValueError, match="all-'attn'"):
            ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              spec=SpecConfig(k=2))

    def test_recurrent_config_refused(self):
        from repro.nn.recurrent import RGLRUConfig
        cfg = _tiny(pattern=("attn", "griffin"), max_seq_len=64,
                    rglru=RGLRUConfig(width=32, conv_width=4))
        params = model_init(KEY, cfg)
        with pytest.raises(ValueError, match="all-'attn'"):
            ContinuousBatcher(params, cfg, batch_size=2, max_len=64,
                              spec=SpecConfig(k=2))


# ---------------------------------------------------------------------------
class TestLossless:
    def test_paged_fp_greedy(self, setup):
        _assert_pair(setup, _reqs())

    def test_paged_fp_sampled(self, setup):
        # seeded temperature sampling: acceptance needs the draft to hit
        # the exact categorical draw, so feed the sampler's own history
        # back long enough for self-repetition to appear
        _assert_pair(setup, _reqs(seeds=True, max_new=32),
                     gen=GenerateConfig(temperature=0.3, top_k=4))

    def test_dense_fp_greedy(self, setup):
        _assert_pair(setup, _reqs(), paged=False, block_size=16,
                     num_blocks=None)

    def test_paged_int8_kv_greedy(self, setup):
        _assert_pair(setup, _reqs(), kv_int8=True)

    def test_paged_int8_kv_sampled(self, setup):
        _assert_pair(setup, _reqs(seeds=True, max_new=32), kv_int8=True,
                     gen=GenerateConfig(temperature=0.3, top_k=4))

    def test_chunked_prefill_mixed_ticks(self, setup):
        # tiny token budget: prompts stream in multi-tick chunks while
        # earlier rows already speculate — the mixed tick carries both
        _assert_pair(setup, _reqs(k=4, plen=30), token_budget=12,
                     prefill_chunk=8)

    def test_engine_vs_standalone_generate(self, setup):
        cfg, params = setup
        req = _reqs(k=1, max_new=16)[0]
        ref = np.asarray(generate(
            params, cfg, jnp.asarray(req.prompt)[None, :],
            GenerateConfig(max_new_tokens=16))[0, len(req.prompt):])
        b = _run(setup, [req], spec=SpecConfig(k=4))
        np.testing.assert_array_equal(b.done[0].output, ref)

    def test_eos_inside_accepted_run(self, setup):
        # Force EOS to land INSIDE an accepted draft, not as a plain
        # decode token: prompt (2,9)* drives this model's greedy tail
        # into a period-2 cycle (A,B,A,B,...).  Teacher-force a prompt
        # that ends mid-cycle and set eos=B: the drafter's first proposal
        # is [B,A,B,A], the verifier accepts it, and the kept run must
        # truncate at the first banked B.
        probe = [Request(uid=0, prompt=_motif_prompt(24, motif=(2, 9)),
                         max_new_tokens=32)]
        out0 = _run(setup, probe).done[0].output.tolist()
        cut = len(out0) - 5
        a, eos = out0[cut], out0[cut + 1]  # continuation = [a, eos, a, ...]
        assert a != eos and out0[cut:] == [a, eos, a, eos, a]  # period 2
        # a != eos ensures the first post-prefill token survives, so the
        # EOS can only arrive through a verified draft
        prompt = np.concatenate([_motif_prompt(24, motif=(2, 9)),
                                 np.asarray(out0[:cut], np.int32)])
        reqs = [Request(uid=0, prompt=prompt, max_new_tokens=16)]
        base, spec_b = _assert_pair(setup, reqs, eos_id=eos)
        out = spec_b.done[0].output.tolist()
        assert out == [a, eos]  # truncated at EOS mid-accepted-run

    def test_max_new_tokens_exact(self, setup):
        # teacher-forced cyclic prompt (same trick as the EOS test): the
        # run accepts drafts from tick one, and max_new_tokens must clamp
        # the banked tokens exactly — the draft cap (k_cap) and the kept
        # loop both respect the remaining room
        probe = [Request(uid=0, prompt=_motif_prompt(24, motif=(2, 9)),
                         max_new_tokens=32)]
        out0 = _run(setup, probe).done[0].output.tolist()
        cut = len(out0) - 7
        a, b = out0[cut], out0[cut + 1]
        assert a != b and out0[cut:] == [a, b, a, b, a, b, a]  # period 2
        prompt = np.concatenate([_motif_prompt(24, motif=(2, 9)),
                                 np.asarray(out0[:cut], np.int32)])
        reqs = [Request(uid=0, prompt=prompt, max_new_tokens=3)]
        _, spec_b = _assert_pair(setup, reqs, spec=SpecConfig(k=5))
        out = spec_b.done[0].output.tolist()
        assert out == out0[cut:cut + 3]  # exact clamp mid-accepted-run

    def test_multi_block_boundary_one_tick(self, setup):
        # block_size 4 with k=6: an accepting tick writes up to 7 tokens,
        # crossing >= 2 block boundaries — _grow_blocks multi-block path
        base, spec_b = _assert_pair(setup, _reqs(max_new=24),
                                    spec=SpecConfig(k=6), block_size=4,
                                    num_blocks=96)
        assert spec_b.spec_accepted >= 6

    def test_prefix_cache_warm_admission(self, setup):
        prompt = _motif_prompt(32)
        reqs = [Request(uid=0, prompt=prompt.copy(), max_new_tokens=12),
                Request(uid=1, prompt=prompt.copy(), max_new_tokens=12)]

        def run(spec):
            b = _engine(setup, spec=spec, prefix_cache=True)
            b.submit(dataclasses.replace(reqs[0], prompt=prompt.copy()))
            b.run()                      # cold request publishes blocks
            b.submit(dataclasses.replace(reqs[1], prompt=prompt.copy()))
            b.run()                      # warm: admitted on cached blocks
            b.audit()
            assert b.shared_admissions >= 1
            return b

        base, spec_b = run(None), run(SpecConfig(k=4))
        assert _outs(base) == _outs(spec_b)
        assert spec_b.spec_accepted > 0
        cold, warm = _outs(spec_b)[0], _outs(spec_b)[1]
        assert cold == warm

    def test_parallel_sampling_branches(self, setup):
        def run(spec):
            b = _engine(setup, spec=spec,
                        gen=GenerateConfig(temperature=0.3, top_k=4))
            b.submit(Request(uid=0, prompt=_motif_prompt(24),
                             max_new_tokens=16, n=3, seed=7))
            b.run()
            b.audit()
            return [o.tolist() for o in b.done[0].outputs]

        assert run(None) == run(SpecConfig(k=3))

    def test_qconfig_int8_w8a8_greedy(self, setup):
        from repro.quant.qconfig import QConfig
        _assert_pair(setup, _reqs(max_new=12), kv_int8=True,
                     qconfig=QConfig(), calib_batches=2)


# ---------------------------------------------------------------------------
class TestAccounting:
    def test_fed_vs_banked_tokens(self, setup):
        b = _engine(setup, spec=SpecConfig(k=4))
        for r in _reqs():
            b.submit(r)
        fed = banked = 0
        multi = False
        while b.queue or any(s.req is not None for s in b.slots):
            b.step()
            assert b.last_tick_new_tokens <= b.last_tick_tokens
            fed += b.last_tick_tokens
            banked += b.last_tick_new_tokens
            dec = sum(1 for s in b.slots
                      if s.req is not None and s.prefill is None)
            if b.last_tick_new_tokens > max(dec, 1):
                multi = True
        # every output token was banked exactly once, and at least one
        # tick banked more than one token per decode row
        assert banked == sum(len(r.output) for r in b.done)
        assert multi
        assert fed >= banked

    def test_min_ticks_left_stays_optimistic(self, setup):
        b = _engine(setup, spec=SpecConfig(k=3))
        req = Request(uid=0, prompt=_motif_prompt(8), max_new_tokens=8)
        req.arrival, req.submit_time = 0, 0.0
        est = b._min_ticks_left(req)
        # prefill fits one chunk; decode is bounded below by full
        # acceptance: ceil(8 / (k+1)) = 2 ticks, not 8
        assert est == 1 + 2

    def test_workload_decode_tpot_improves(self, setup):
        # virtual-clock open loop over a repetitive trace: charging FED
        # tokens, speculation still wins because banked tokens per tick
        # outgrow the per-token cost — decode TPOT must drop
        trace = [TraceEntry(uid=u, arrival=0.02 * u, tier="t", priority=0,
                            prompt=_motif_prompt(24),
                            max_new_tokens=24, deadline=1e9)
                 for u in range(6)]
        cost = TickCostModel(base=2e-3, per_token=1e-4)

        def tpot(spec):
            rep = run_workload(_engine(setup, spec=spec), list(trace), cost)
            assert rep.decode_tokens > 0
            assert rep.goodput_tokens == 6 * 24
            return rep.decode_tpot

        assert tpot(SpecConfig(k=4)) < tpot(None)


# ---------------------------------------------------------------------------
class TestChaosMidSpeculation:
    def test_storm_plans_audit_clean_survivors_exact(self, setup):
        """Preempt/swap/cancel storms against a SPECULATING int8-KV
        engine: the harness audits every tick, survivors must be
        token-exact vs the plain non-speculative oracle — chaos may
        delay speculation (stale draft tails dropped at preempt,
        swapped with the blocks, recomputed on resume), never leak it
        into outputs."""
        cfg, params = setup
        reqs = _reqs(k=5, plen=20, max_new=10)
        oracle = _outs(_run(setup, reqs, spec=None, kv_int8=True,
                            block_size=4, num_blocks=64))
        for seed in range(3):
            plan = FaultPlan.random(seed, ticks=16, p_storm=0.3,
                                    p_deny=0.2)
            b = _engine(setup, spec=SpecConfig(k=4), kv_int8=True,
                        block_size=4, num_blocks=64,
                        swap_break_even_tokens=8,
                        on_pool_exhausted="shed")
            for r in reqs:
                b.submit(dataclasses.replace(r, prompt=r.prompt.copy(),
                                             output=None))
            h = ChaosHarness(b, plan)
            h.run()
            b.audit()
            assert b.allocator.available == b.num_blocks
            for req in b.done:
                if req.uid >= ChaosHarness.JUNK_UID0:
                    continue
                assert req.output.tolist() == oracle[req.uid]

    def test_manual_preempt_mid_speculation_exact(self, setup):
        """Force preemption while rows hold rejected-draft cache tails:
        recompute-resume and swap-resume must both replay the identical
        stream (the stale tail is never part of resumable state)."""
        reqs = _reqs(k=3, plen=20, max_new=16)
        oracle = _outs(_run(setup, reqs, spec=None))
        for swap in (None, 8):
            b = _engine(setup, spec=SpecConfig(k=4),
                        swap_break_even_tokens=swap)
            for r in reqs:
                b.submit(dataclasses.replace(r, prompt=r.prompt.copy(),
                                             output=None))
            rng = np.random.default_rng(0)
            ticks = 0
            while b.queue or any(s.req is not None for s in b.slots):
                if ticks % 3 == 2:
                    live = [i for i, s in enumerate(b.slots)
                            if s.req is not None and s.prefill is None]
                    if live:
                        b.preempt_slot(int(rng.choice(live)))
                b.step()
                b.audit()
                ticks += 1
                assert ticks < 400
            assert _outs(b) == oracle
