"""End-to-end system behaviour: train -> evaluate -> quantize -> serve,
plus data-pipeline determinism (the fault-tolerance contract)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import apply_method
from repro.configs.paper_models import opt_tiny
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.models import model_apply, model_init
from repro.optim import AdamWConfig
from repro.quant import QConfig, calibrate, evaluate_perplexity
from repro.serving import GenerateConfig, generate
from repro.train import LoopConfig, TrainTask, run_training
from repro.train.losses import clm_loss

pytestmark = pytest.mark.slow  # end-to-end train->quantize->serve pipelines

VOCAB, SEQ = 128, 32


def _data(bs=8):
    return SyntheticLM(SyntheticLMConfig(vocab_size=VOCAB, seq_len=SEQ,
                                         batch_size=bs))


def test_data_pipeline_deterministic_and_learnable():
    d1, d2 = _data(), _data()
    b1, b2 = d1.batch(17), d2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(17)["tokens"], d1.batch(18)["tokens"])
    toks = d1.batch(0)["tokens"]
    assert len(np.unique(toks)) > 16


def test_train_quantize_serve_clipped_softmax():
    """The paper's pipeline on the paper's method, end to end."""
    cfg = apply_method(opt_tiny(vocab=VOCAB, seq_len=SEQ), "clipped_softmax",
                       alpha=4.0)
    task = TrainTask(cfg=cfg, optimizer=AdamWConfig(lr=3e-3))
    data = _data()
    from repro.train import evaluate, init_train_state
    init_ppl, _ = evaluate(task, init_train_state(
        jax.random.PRNGKey(0), task).params, data, 2, "clm")
    out = run_training(task, data, LoopConfig(
        total_steps=30, eval_every=15, eval_batches=2, log_every=0))
    assert out["history"]["eval_ppl"][-1] < init_ppl   # learned vs untrained
    params = out["state"].params

    def apply_fn(p, batch, ctx):
        logits, _ = model_apply(p, cfg, batch, ctx=ctx)
        return logits

    def loss_fn(p, batch, ctx):
        from repro.quant import QuantContext
        ctx = ctx if ctx is not None else QuantContext(None)
        logits, _ = model_apply(p, cfg, batch, ctx=ctx)
        return clm_loss(logits, jnp.asarray(batch["labels"]))

    batches = [jax.tree_util.tree_map(jnp.asarray, data.batch(100 + i))
               for i in range(4)]
    ctx = calibrate(apply_fn, params, batches, QConfig(), 4)
    fp = evaluate_perplexity(loss_fn, params, batches, None, 2)
    q8 = evaluate_perplexity(loss_fn, params, batches, ctx, 2)
    assert q8 < fp * 1.25, (fp, q8)

    gcfg = dataclasses.replace(cfg, max_seq_len=64)
    toks = generate(params, gcfg, jnp.ones((2, 8), jnp.int32) * 7,
                    GenerateConfig(max_new_tokens=8))
    assert toks.shape == (2, 16)
    assert int(toks.max()) < VOCAB


def test_gated_attention_trains():
    cfg = apply_method(opt_tiny(vocab=VOCAB, seq_len=SEQ), "gated_attention",
                       pi_init=0.5)
    task = TrainTask(cfg=cfg, optimizer=AdamWConfig(lr=3e-3))
    out = run_training(task, _data(), LoopConfig(
        total_steps=20, eval_every=10, eval_batches=2, log_every=0))
    assert out["history"]["eval_ppl"][-1] < out["history"]["eval_ppl"][0]
