"""Training loop, optimizer, checkpoint/restart (fault tolerance),
microbatching equivalence, gradient compression."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.paper_models import opt_tiny
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, clip_by_global_norm,
    compress_grads, ef_init, linear_warmup_linear_decay,
)
from repro.train import (
    LoopConfig, TrainTask, init_train_state, make_train_step, run_training,
)

KEY = jax.random.PRNGKey(0)

pytestmark = pytest.mark.slow  # training-loop + checkpoint round-trips


def _tiny_task(**kw):
    cfg = opt_tiny(vocab=128, seq_len=32)
    return TrainTask(cfg=cfg, loss_kind="clm",
                     optimizer=AdamWConfig(lr=3e-3), **kw)


def _data(vocab=128, seq=32, bs=4):
    return SyntheticLM(SyntheticLMConfig(vocab_size=vocab, seq_len=seq,
                                         batch_size=bs))


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.5, weight_decay=0.0, grad_clip_norm=None)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        np.testing.assert_allclose(params["w"], 0.0, atol=1e-2)

    def test_weight_decay_mask(self):
        params = {"l": {"w": jnp.ones(3), "b": jnp.ones(3)},
                  "ln": {"scale": jnp.ones(3)}}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=1e-2, weight_decay=1.0, grad_clip_norm=None)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        new, _, _ = adamw_update(zeros, state, params, cfg)
        assert float(new["l"]["w"][0]) < 1.0       # decayed
        assert float(new["l"]["b"][0]) == 1.0      # masked
        assert float(new["ln"]["scale"][0]) == 1.0 # masked
        # paper App. B.3: LN-gamma decay switch
        cfg2 = dataclasses.replace(cfg, decay_norm_scales=True)
        new2, _, _ = adamw_update(zeros, state, params, cfg2)
        assert float(new2["ln"]["scale"][0]) < 1.0

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-5)

    def test_schedule(self):
        f = linear_warmup_linear_decay(10, 100)
        assert float(f(0)) == 0.0
        assert float(f(10)) == pytest.approx(1.0)
        assert float(f(100)) == pytest.approx(0.0, abs=1e-6)

    def test_compression_error_feedback(self):
        """Error feedback conserves mass exactly: emitted + residual equals
        the sum of inputs (what int8 drops is never lost), and components
        above the quantization step are transmitted accurately."""
        g = {"w": jnp.array([1e-6, 1.0, -0.5])}
        ef = ef_init(g)
        acc = jnp.zeros(3)
        for _ in range(50):
            deq, ef = compress_grads(g, ef)
            acc = acc + deq["w"]
        np.testing.assert_allclose(acc + ef.residual["w"], 50 * g["w"],
                                   rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(acc[1:] / 50, g["w"][1:], rtol=0.02)


class TestTraining:
    def test_loss_decreases(self):
        out = run_training(_tiny_task(), _data(), LoopConfig(
            total_steps=40, eval_every=20, eval_batches=2, log_every=0))
        h = out["history"]
        assert h["eval_ppl"][-1] < h["eval_ppl"][0]

    def test_microbatch_equivalence(self):
        t1 = _tiny_task()
        t2 = _tiny_task(microbatch=2)
        s1 = init_train_state(KEY, t1)
        s2 = init_train_state(KEY, t2)
        batch = jax.tree_util.tree_map(jnp.asarray, _data(bs=4).batch(0))
        s1n, m1 = jax.jit(make_train_step(t1))(s1, batch)
        s2n, m2 = jax.jit(make_train_step(t2))(s2, batch)
        a = jax.tree_util.tree_leaves(s1n.params)[0]
        b = jax.tree_util.tree_leaves(s2n.params)[0]
        np.testing.assert_allclose(a, b, atol=2e-5)

    def test_grad_compress_step_runs(self):
        t = _tiny_task(grad_compress=True)
        s = init_train_state(KEY, t)
        batch = jax.tree_util.tree_map(jnp.asarray, _data().batch(0))
        s, m = jax.jit(make_train_step(t))(s, batch)
        assert np.isfinite(float(m["loss"]))


class TestCheckpoint:
    def test_roundtrip_and_keep_k(self):
        task = _tiny_task()
        state = init_train_state(KEY, task)
        with tempfile.TemporaryDirectory() as d:
            for s in (5, 10, 15, 20):
                save_checkpoint(d, s, state, keep=2)
            names = sorted(os.listdir(d))
            assert names == ["step_00000015", "step_00000020"]
            restored, step = restore_checkpoint(d, state)
            assert step == 20
            np.testing.assert_allclose(
                jax.tree_util.tree_leaves(state.params)[0],
                jax.tree_util.tree_leaves(restored.params)[0])

    def test_structure_mismatch_rejected(self):
        task = _tiny_task()
        state = init_train_state(KEY, task)
        other = init_train_state(
            KEY, TrainTask(cfg=opt_tiny(vocab=64, seq_len=32)))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, state)
            with pytest.raises(ValueError):
                restore_checkpoint(d, other)

    def test_no_partial_checkpoint_visible(self):
        """Atomic commit: only fully-renamed step dirs count."""
        task = _tiny_task()
        state = init_train_state(KEY, task)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, state)
            os.makedirs(os.path.join(d, "step_00000009.tmp"))
            assert latest_step(d) == 7

    def test_resume_continues_training(self):
        """Kill-and-restart: the loop resumes from the saved step."""
        task = _tiny_task()
        with tempfile.TemporaryDirectory() as d:
            loop = LoopConfig(total_steps=10, eval_every=0, log_every=0,
                              ckpt_every=5, ckpt_dir=d)
            run_training(task, _data(), loop)
            assert latest_step(d) == 10
            # restart with a longer horizon: resumes at 10, not 0
            loop2 = LoopConfig(total_steps=12, eval_every=0, log_every=0,
                               ckpt_every=5, ckpt_dir=d)
            out = run_training(task, _data(), loop2)
            assert int(out["state"].step) == 12
