"""Open-loop workload harness: per-seed determinism of trace generation
and virtual-clock runs, per-tier goodput/TTFT reporting, deadline expiry,
trace-driven cancellation, and priority protection under overload."""
import dataclasses

import jax
import numpy as np

from repro.configs.paper_models import opt_tiny
from repro.models import model_init
from repro.serving import (
    ContinuousBatcher,
    Request,
    TickCostModel,
    TierSpec,
    WorkloadConfig,
    generate_trace,
    run_workload,
)

KEY = jax.random.PRNGKey(0)


def _setup(max_len=160):
    cfg = dataclasses.replace(opt_tiny(vocab=64, seq_len=32),
                              max_seq_len=max_len)
    return cfg, model_init(KEY, cfg)


def _batcher(params, cfg, **kw):
    base = dict(batch_size=4, max_len=160, token_budget=64, paged=True,
                num_blocks=48, block_size=8, debug_audit=True)
    base.update(kw)
    return ContinuousBatcher(params, cfg, **base)


def _wcfg(**kw):
    base = dict(seed=7, n_requests=14, rate=30.0, prompt_max=40, out_max=10)
    base.update(kw)
    return WorkloadConfig(**base)


def test_trace_deterministic_per_seed():
    a = generate_trace(_wcfg(cancel_frac=0.25))
    b = generate_trace(_wcfg(cancel_frac=0.25))
    assert len(a) == len(b) == 14
    for x, y in zip(a, b):
        assert x.uid == y.uid and x.arrival == y.arrival
        assert x.tier == y.tier and x.priority == y.priority
        np.testing.assert_array_equal(x.prompt, y.prompt)
        assert x.deadline == y.deadline and x.cancel_at == y.cancel_at
    # different seed -> different trace (arrivals almost surely differ)
    c = generate_trace(_wcfg(seed=8, cancel_frac=0.25))
    assert any(x.arrival != y.arrival for x, y in zip(a, c))


def test_trace_shape_sanity():
    for e in generate_trace(_wcfg()):
        assert 1 <= len(e.prompt) <= 40
        assert 1 <= e.max_new_tokens <= 10
        assert e.deadline > e.arrival
        assert e.prompt.dtype == np.int32


def test_run_deterministic_and_reports_per_tier():
    cfg, params = _setup()
    trace = generate_trace(_wcfg())
    r1 = run_workload(_batcher(params, cfg), trace, TickCostModel())
    r2 = run_workload(_batcher(params, cfg), trace, TickCostModel())
    assert r1.ticks == r2.ticks
    assert r1.goodput_tokens == r2.goodput_tokens
    assert r1.delivered_tokens == r2.delivered_tokens
    assert abs(r1.duration - r2.duration) < 1e-12
    assert r1.stall_p99 == r2.stall_p99
    # per-tier accounting covers every traced request exactly once
    offered = sum(t.offered for t in r1.tiers.values())
    accounted = sum(t.done + sum(t.failed.values())
                    for t in r1.tiers.values())
    assert offered == len(trace) == accounted
    for tr in r1.tiers.values():
        if tr.ttft:
            assert tr.ttft_p50 <= tr.ttft_p99
    assert r1.table()  # renders without blowing up


def test_impossible_deadlines_expire_not_hang():
    cfg, params = _setup()
    tight = (TierSpec("doomed", weight=1.0, priority=0, ttft_slo=1e-9,
                      tpot_slo=1e-9),)
    trace = generate_trace(_wcfg(tiers=tight, n_requests=6))
    rep = run_workload(_batcher(params, cfg), trace, TickCostModel())
    tr = rep.tiers["doomed"]
    # every request left the engine (no hang), none inside its SLO, and
    # the misses are recorded as expired/shed rather than silently done
    assert tr.done + sum(tr.failed.values()) == 6
    assert rep.goodput_tokens == 0
    assert sum(tr.failed.values()) > 0


def test_cancellations_are_honored():
    cfg, params = _setup()
    # slow virtual clock so cancel_at lands while requests are in flight
    slow = TickCostModel(base=0.5, per_token=0.1)
    trace = generate_trace(_wcfg(cancel_frac=0.9, n_requests=8))
    rep = run_workload(_batcher(params, cfg), trace, slow)
    cancelled = sum(t.failed.get("cancelled", 0) for t in rep.tiers.values())
    assert cancelled > 0
    # cancelled requests never appear among completions
    done = sum(t.done for t in rep.tiers.values())
    assert done + sum(sum(t.failed.values()) for t in rep.tiers.values()) \
        == len(trace)


def test_overload_protects_high_priority():
    """Under an offered load the engine cannot fully serve, the
    interactive tier's in-SLO fraction must not fall below batch's: SLO
    shedding + priority admission sacrifice low-priority work first."""
    cfg, params = _setup()
    tiers = (TierSpec("gold", weight=0.5, priority=2, ttft_slo=2.0,
                      tpot_slo=0.3),
             TierSpec("scav", weight=0.5, priority=0, ttft_slo=2.0,
                      tpot_slo=0.3))
    trace = generate_trace(_wcfg(tiers=tiers, n_requests=24, rate=400.0,
                                 prompt_max=32, out_max=8))
    # slow ticks -> the engine is genuinely saturated
    rep = run_workload(
        _batcher(params, cfg, batch_size=2, token_budget=32, num_blocks=24),
        trace, TickCostModel(base=0.15, per_token=0.02))
    gold, scav = rep.tiers["gold"], rep.tiers["scav"]
    assert gold.offered > 0 and scav.offered > 0
    frac = lambda t: t.in_slo / t.offered  # noqa: E731
    assert frac(gold) >= frac(scav)


def test_first_token_time_drives_ttft():
    cfg, params = _setup()
    b = _batcher(params, cfg)
    b.submit(Request(uid=0, prompt=np.arange(4, 10, dtype=np.int32),
                     max_new_tokens=3))
    t = 0.0
    while b.queue or any(s.req is not None for s in b.slots):
        b.step(now=t)
        t += 0.25
    (req,) = b.done
    assert req.submit_time == 0.0
    assert req.first_token_time is not None
    assert req.first_token_time <= req.finish_time
